import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

Lowers + compiles the production step function for every
(architecture × input shape × mesh) combination on 512 placeholder host
devices, proving the sharding configuration is coherent, and records
memory_analysis / HLO statistics (FLOPs, HBM bytes, collective bytes — via
``repro.launch.hlo_stats``, which corrects for while-loop trip counts) into
JSON artifacts consumed by §Roofline.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch deepseek-7b \
        --shape train_4k [--multi-pod] [--out benchmarks/results/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.common.types import INPUT_SHAPES, MLLMConfig, ModelConfig, ShapeSpec
from repro.configs import ASSIGNED, ArchSpec, get_config
from repro.core.communicator import make_communicator
from repro.core.profiling.flops import model_flops_6nd, module_flops
from repro.launch.hlo_stats import analyze
from repro.launch.mesh import batch_axes, make_production_mesh, model_axes
from repro.models import mllm as mllm_lib
from repro.models import model as model_lib
from repro.models.model import FwdCtx
from repro.serve.steps import make_decode_step, make_prefill_step
from repro.sharding.partition import (
    AxisAssignment,
    ModuleAssignment,
    param_specs,
    opt_state_specs,
    sanitize_spec,
)
from repro.train.optim import AdamWConfig, adamw_init
from repro.train.step import make_train_step

# per-arch microbatch counts for train_4k (memory-driven)
N_MB = {"default": 8, "jamba-v0.1-52b": 16, "mixtral-8x7b": 16,
        "starcoder2-15b": 16}
# per-arch MoE dispatch chunk (tokens)
MOE_CHUNK = {"default": 8192}

MEM_CAP_BYTES = 16e9        # v5e HBM


# --------------------------------------------------------------------------- #
# Sharding plans
# --------------------------------------------------------------------------- #
def make_assignment(mesh, spec: ArchSpec, *, heterogeneous: bool = True,
                    fsdp: bool = True) -> ModuleAssignment:
    """DFLOP plan on the fixed mesh: LLM uses the model axis for tensor
    sharding; the encoder (small, batch-rich) runs tp=1 with the model axis
    joined to its batch sharding — the SPMD realization of independent
    per-module 3D parallelism (DESIGN.md §2)."""
    b, m = batch_axes(mesh), model_axes(mesh)
    zero = b          # ZeRO over all batch axes (pod + data on multi-pod)
    llm = AxisAssignment(batch=b, tensor=m, zero=zero, fsdp=fsdp)
    enc = None
    if spec.is_mllm:
        if heterogeneous:
            enc = AxisAssignment(batch=b + m, tensor=(), zero=zero, fsdp=fsdp)
        else:
            enc = AxisAssignment(batch=b, tensor=m, zero=zero, fsdp=fsdp)
    return ModuleAssignment(llm=llm, encoder=enc)


def moe_constrain_fn(mesh, cfg: ModelConfig, assignment: AxisAssignment):
    """Sharding constraint for the (E, C, d) MoE dispatch buffers: expert
    parallelism when E divides the tensor axes, else shard capacity over the
    batch axes (DESIGN.md §4 notes on granite/mixtral)."""
    if cfg.n_experts == 0:
        return None
    t = assignment.tensor
    tsize = int(np.prod([mesh.shape[a] for a in t], initial=1))
    if t and cfg.n_experts % tsize == 0:
        spec = P(tuple(t), tuple(assignment.batch) or None, None)
    else:
        spec = P(None, tuple(assignment.batch) or None, None)

    def constrain(x):
        s = sanitize_spec(spec, x.shape, mesh)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, s))

    return constrain


def block_gather_constrain(mesh, blocks_shapes, assignment: AxisAssignment):
    """ZeRO-3 weight gather for one scanned block: constrain the sliced
    block params to their non-FSDP layout (tensor-sharded, replicated over
    the zero axes).  Applied inside the layer scan it is loop-variant — the
    all-gather is per-block, and its transpose reduce-scatters dW."""
    if not (assignment.fsdp and assignment.zero):
        return None
    a2 = dataclasses.replace(assignment, fsdp=False)
    specs = param_specs({"blocks": blocks_shapes},
                        ModuleAssignment(llm=a2), mesh)["blocks"]

    def drop0(s):
        return P(*list(s)[1:]) if len(s) else s

    specs = jax.tree.map(drop0, specs, is_leaf=lambda x: isinstance(x, P))

    def constrain(lp, j):
        return jax.tree.map(
            lambda x, sp: jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, sanitize_spec(sp, x.shape, mesh))),
            lp, specs[f"pos{j}"])

    return constrain


def hidden_constrain_fn(mesh, assignment: AxisAssignment):
    """Anchor (B, S, d) activations: batch over the module's batch axes."""
    b = tuple(assignment.batch)

    def constrain(x):
        s = sanitize_spec(P(b or None, None, None), x.shape, mesh)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, s))

    return constrain


def logits_constrain_fn(mesh, cfg: ModelConfig, assignment: AxisAssignment):
    """Shard the (B, S, vocab) logits over the tensor axes on the vocab dim
    — keeps the fp32 CE working set per chip small for 200k+ vocabs."""
    b = tuple(assignment.batch)
    t = tuple(assignment.tensor)
    spec = P(b or None, None, t or None)

    def constrain(x):
        s = sanitize_spec(spec, x.shape, mesh)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, s))

    return constrain


def cache_specs(cfg: ModelConfig, caches_shapes, mesh, assignment: AxisAssignment,
                batch: int):
    """KV/state cache PartitionSpecs.  Sequence dim of KV caches shards over
    the model axis (flash-decoding style) — kv-head counts (1–8) rarely
    divide a 16-wide axis; for batch=1 long-context the data axes join in."""
    b = tuple(assignment.batch)
    m = tuple(assignment.tensor)
    seq_axes = m if batch > 1 else tuple(assignment.batch) + m

    def rule(path: str, leaf):
        shape = leaf.shape
        if path.endswith("/k") or path.endswith("/v"):
            spec = P(None, b or None, seq_axes or None, None, None)
        elif path.endswith("/kpos"):
            # per-row validity: (n_blocks, B, C) — row dim follows k/v batch
            spec = P(None, b or None, seq_axes or None)
        elif path.endswith("/conv"):
            spec = P(None, b or None, None, m or None)
        elif path.endswith("/ssm"):
            spec = P(None, b or None, m or None, None)
        elif path.endswith("/wkv"):
            spec = P(None, b or None, m or None, None, None)
        elif path.endswith("_prev"):
            spec = P(None, b or None, m or None)
        else:
            spec = P()
        return sanitize_spec(spec, shape, mesh)

    from repro.common.pytree import tree_map_with_path_str

    return tree_map_with_path_str(rule, caches_shapes)


# --------------------------------------------------------------------------- #
# Batch specs (ShapeDtypeStructs) per family × shape kind
# --------------------------------------------------------------------------- #
def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(
        shape, dtype, sharding=NamedSharding(mesh, sanitize_spec(spec, shape, mesh)))


def media_split(spec: ArchSpec, seq_len: int) -> tuple[int, int, int]:
    """(media items, encoder tokens, text tokens) for an MLLM sample whose
    LLM sequence is `seq_len` (≈half media, half text)."""
    mcfg: MLLMConfig = spec.desc
    tpm = spec.tokens_per_media_item or mcfg.tokens_per_item_out or 196
    n_items = max(1, (seq_len // 2) // tpm)
    enc_tokens = n_items * mcfg.stub.n_tokens
    text = seq_len - n_items * tpm
    return n_items, enc_tokens, text


def input_specs(spec: ArchSpec, shape: ShapeSpec, mesh, n_mb: int):
    """ShapeDtypeStruct stand-ins for the step's data inputs (train kind)."""
    assignment = make_assignment(mesh, spec)
    b_axes = tuple(assignment.llm.batch)
    desc = spec.desc
    mb = shape.global_batch // n_mb
    S = shape.seq_len
    bspec3 = P(None, b_axes or None, None)
    bspec4 = P(None, b_axes or None, None, None)
    if isinstance(desc, MLLMConfig):
        n_items, enc_tok, text = media_split(spec, S)
        e_spec = P(None, tuple(assignment.for_module("encoder").batch) or None,
                   None, None)
        return {
            "media_embeds": _sds((n_mb, mb, enc_tok, desc.stub.embed_dim),
                                 jnp.bfloat16, mesh, e_spec),
            "media_mask": _sds((n_mb, mb, enc_tok), jnp.int32, mesh, bspec3),
            "text_tokens": _sds((n_mb, mb, text), jnp.int32, mesh, bspec3),
            "text_mask": _sds((n_mb, mb, text), jnp.int32, mesh, bspec3),
            "labels": _sds((n_mb, mb, text), jnp.int32, mesh, bspec3),
        }
    if desc.input_embed_dim > 0:
        return {
            "frame_embeds": _sds((n_mb, mb, S, desc.input_embed_dim),
                                 jnp.bfloat16, mesh, bspec4),
            "labels": _sds((n_mb, mb, S), jnp.int32, mesh, bspec3),
        }
    return {
        "tokens": _sds((n_mb, mb, S), jnp.int32, mesh, bspec3),
        "labels": _sds((n_mb, mb, S), jnp.int32, mesh, bspec3),
        "segment_ids": _sds((n_mb, mb, S), jnp.int32, mesh, bspec3),
        "positions": _sds((n_mb, mb, S), jnp.int32, mesh, bspec3),
    }


def _shapes_of(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


# --------------------------------------------------------------------------- #
# Step builders
# --------------------------------------------------------------------------- #
def _dryrun_cfg(cfg: ModelConfig) -> ModelConfig:
    return dataclasses.replace(cfg, dtype="bfloat16", param_dtype="bfloat16")


def _dryrun_desc(spec: ArchSpec):
    d = spec.desc
    if isinstance(d, MLLMConfig):
        return dataclasses.replace(d, encoder=_dryrun_cfg(d.encoder),
                                   llm=_dryrun_cfg(d.llm))
    return _dryrun_cfg(d)


def build_train(spec: ArchSpec, shape: ShapeSpec, mesh):
    desc = _dryrun_desc(spec)
    assignment = make_assignment(mesh, spec)
    n_mb = N_MB.get(spec.arch_id, N_MB["default"])
    llm_cfg = desc.llm if isinstance(desc, MLLMConfig) else desc

    params_shapes = jax.eval_shape(
        lambda: (mllm_lib.init if isinstance(desc, MLLMConfig)
                 else model_lib.init)(jax.random.PRNGKey(0), desc))
    opt_shapes = jax.eval_shape(lambda: adamw_init(params_shapes))
    pspecs = param_specs(params_shapes, assignment, mesh)
    moment_specs = opt_state_specs(params_shapes, pspecs, assignment, mesh)
    ospecs = {"m": moment_specs, "v": moment_specs, "step": P()}

    batch = input_specs(spec, shape, mesh, n_mb)
    communicator = None
    if isinstance(desc, MLLMConfig):
        communicator = make_communicator(mesh, assignment.for_module("encoder"),
                                         assignment.llm)
    ctx = FwdCtx(mode="train", attn_impl="chunked", attn_block=1024,
                 ssm_impl="chunked", moe_impl="ep",
                 capacity_factor=1.25,
                 moe_chunk_tokens=MOE_CHUNK.get(spec.arch_id,
                                                MOE_CHUNK["default"]),
                 moe_constrain=moe_constrain_fn(mesh, llm_cfg, assignment.llm),
                 hidden_constrain=hidden_constrain_fn(mesh, assignment.llm),
                 logits_constrain=logits_constrain_fn(mesh, llm_cfg,
                                                      assignment.llm),
                 shard_ctx=(mesh, tuple(assignment.llm.batch),
                            tuple(assignment.llm.tensor)))
    from repro.sharding.vocab_ce import make_vocab_parallel_ce

    vocab_ce = make_vocab_parallel_ce(
        mesh, tuple(assignment.llm.batch), tuple(assignment.llm.tensor),
        llm_cfg.vocab_size, tied=llm_cfg.tie_embeddings)
    # ZeRO-3 per-block weight gathers (reduce-scattered dW in the backward)
    enc_ctx = None
    if isinstance(desc, MLLMConfig):
        llm_blocks = params_shapes["llm"]["blocks"]
        enc_blocks = params_shapes["encoder"]["blocks"]
        ctx.block_constrain = block_gather_constrain(mesh, llm_blocks,
                                                     assignment.llm)
        enc_ctx = dataclasses.replace(
            ctx, moe_constrain=None, logits_constrain=None,
            block_constrain=block_gather_constrain(
                mesh, enc_blocks, assignment.for_module("encoder")))
    else:
        ctx.block_constrain = block_gather_constrain(
            mesh, params_shapes["blocks"], assignment.llm)
    step = make_train_step(desc, AdamWConfig(), ctx=ctx,
                           communicator=communicator, vocab_ce=vocab_ce,
                           enc_ctx=enc_ctx)

    def wrapped(params, opt_state, batch):
        return step(params, opt_state, batch, 1e-4)

    in_sh = (jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs),
             jax.tree.map(lambda s: NamedSharding(mesh, s), ospecs,
                          is_leaf=lambda x: isinstance(x, P)),
             jax.tree.map(lambda b: b.sharding, batch))
    out_sh = (in_sh[0], in_sh[1], NamedSharding(mesh, P()))
    jitted = jax.jit(wrapped, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(0, 1))
    args = (params_shapes, opt_shapes, batch)
    return jitted, args, {"n_mb": n_mb, "assignment": "dflop-heterogeneous"}


def build_prefill(spec: ArchSpec, shape: ShapeSpec, mesh):
    desc = _dryrun_desc(spec)
    # FSDP-sharded weights WITHOUT explicit per-block gathers: for the
    # forward-only prefill, XLA's own slice-wise handling of scan-xs weights
    # is the most memory-efficient option measured (the CPU backend converts
    # bf16 dot operands to f32; resident model-axis-only weights double, and
    # explicit gathers add copies).
    assignment = make_assignment(mesh, spec, fsdp=True)
    llm_cfg = desc.llm if isinstance(desc, MLLMConfig) else desc
    b_axes = tuple(assignment.llm.batch)
    B, S = shape.global_batch, shape.seq_len
    params_shapes = jax.eval_shape(
        lambda: (mllm_lib.init if isinstance(desc, MLLMConfig)
                 else model_lib.init)(jax.random.PRNGKey(0), desc))
    llm_blocks = (params_shapes["llm"]["blocks"]
                  if isinstance(desc, MLLMConfig)
                  else params_shapes["blocks"])
    ctx = FwdCtx(mode="prefill", remat=False, attn_impl="chunked",
                 attn_block=1024, ssm_impl="chunked", moe_impl="ep",
                 capacity_factor=1.25,
                 moe_chunk_tokens=8192,
                 moe_constrain=moe_constrain_fn(mesh, llm_cfg, assignment.llm),
                 hidden_constrain=hidden_constrain_fn(mesh, assignment.llm),
                 logits_constrain=logits_constrain_fn(mesh, llm_cfg,
                                                      assignment.llm))

    if isinstance(desc, MLLMConfig):
        n_items, enc_tok, text = media_split(spec, S)
        e_spec = P(tuple(assignment.for_module("encoder").batch) or None,
                   None, None)
        batch = {
            "media_embeds": _sds((B, enc_tok, desc.stub.embed_dim),
                                 jnp.bfloat16, mesh, e_spec),
            "media_mask": _sds((B, enc_tok), jnp.int32, mesh,
                               P(b_axes or None, None)),
            "text_tokens": _sds((B, text), jnp.int32, mesh,
                                P(b_axes or None, None)),
            "text_mask": _sds((B, text), jnp.int32, mesh,
                              P(b_axes or None, None)),
        }
        communicator = make_communicator(mesh, assignment.for_module("encoder"),
                                         assignment.llm)

        ctx = dataclasses.replace(ctx, return_hidden=True)

        def prefill(params, batch):
            # serving prefill: last-position logits only (next token)
            h, _ = mllm_lib.forward_train(
                params, desc, {**batch, "labels": batch["text_tokens"]},
                ctx=ctx, communicator=communicator)
            from repro.models.layers import embed as embed_lib
            h_last = h[:, -1:]
            llm_p = params["llm"]
            if desc.llm.tie_embeddings or "unembed" not in llm_p:
                return embed_lib.decode(llm_p["embed"], h_last)
            return embed_lib.unembed(llm_p["unembed"], h_last)
    elif desc.input_embed_dim > 0:
        batch = {"frame_embeds": _sds((B, S, desc.input_embed_dim),
                                      jnp.bfloat16, mesh,
                                      P(b_axes or None, None, None))}
        prefill = make_prefill_step(desc, ctx)
    else:
        batch = {"tokens": _sds((B, S), jnp.int32, mesh, P(b_axes or None, None))}
        prefill = make_prefill_step(desc, ctx)

    assignment_full = assignment
    pspecs = param_specs(params_shapes, assignment_full, mesh)
    in_sh = (jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs),
             jax.tree.map(lambda b: b.sharding, batch))
    m_axes = tuple(assignment.llm.tensor)
    msize = int(np.prod([mesh.shape[a] for a in m_axes], initial=1))
    vocab_spec = m_axes if (m_axes and llm_cfg.vocab_size % msize == 0) else None
    out_spec = NamedSharding(mesh, P(b_axes or None, None, vocab_spec))
    jitted = jax.jit(prefill, in_shardings=in_sh, out_shardings=out_spec)
    return jitted, (params_shapes, batch), {"assignment": "dflop-heterogeneous"}


def build_decode(spec: ArchSpec, shape: ShapeSpec, mesh):
    desc = _dryrun_desc(spec)
    llm_cfg = desc.llm if isinstance(desc, MLLMConfig) else desc
    # FSDP weights + per-block ZeRO-3 gathers inside the decode layer scan:
    # the gathers are loop-variant (one block per iteration), so weights stay
    # data-sharded at rest and only one block's gathered copy is live —
    # required for the 47-52B MoE/hybrid archs to fit 16 GB at decode.
    assignment = make_assignment(mesh, spec, fsdp=True)
    a = assignment.llm
    B, S = shape.global_batch, shape.seq_len
    params_shapes = jax.eval_shape(
        lambda: model_lib.init(jax.random.PRNGKey(0), llm_cfg))
    if isinstance(desc, MLLMConfig):
        full = jax.eval_shape(lambda: mllm_lib.init(jax.random.PRNGKey(0), desc))
        pspecs_full = param_specs(full, assignment, mesh)
        pspecs = pspecs_full["llm"]
    else:
        pspecs = param_specs(params_shapes, assignment, mesh)
    caches_shapes = jax.eval_shape(
        lambda: model_lib.init_cache(llm_cfg, B, S, kv_dtype=jnp.bfloat16))
    cspecs = cache_specs(llm_cfg, caches_shapes, mesh, a, B)
    b_axes = tuple(a.batch)
    tok = _sds((B,), jnp.int32, mesh, P(b_axes if B > 1 else None))

    blocks_shapes = (full["llm"]["blocks"] if isinstance(desc, MLLMConfig)
                     else params_shapes["blocks"])
    decode_ctx = FwdCtx(mode="decode", remat=False,
                        block_constrain=block_gather_constrain(
                            mesh, blocks_shapes, assignment.llm))
    decode = make_decode_step(llm_cfg, ctx=decode_ctx)

    in_sh = (jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs),
             jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs,
                          is_leaf=lambda x: isinstance(x, P)),
             tok.sharding, NamedSharding(mesh, P()))
    out_sh = (NamedSharding(mesh, P(b_axes if B > 1 else None, None)),
              jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs,
                           is_leaf=lambda x: isinstance(x, P)))
    jitted = jax.jit(decode, in_shardings=in_sh, out_shardings=out_sh,
                     donate_argnums=(1,))
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    args = (params_shapes, caches_shapes, jax.ShapeDtypeStruct((B,), jnp.int32),
            pos)
    return jitted, args, {"cache_len": S, "assignment": "dflop-heterogeneous"}


BUILDERS = {"train": build_train, "prefill": build_prefill,
            "decode": build_decode}


# --------------------------------------------------------------------------- #
# Runner
# --------------------------------------------------------------------------- #
def run_one(arch: str, shape_name: str, multi_pod: bool,
            out_dir: Optional[str] = None, verbose: bool = True) -> dict:
    spec = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    support = spec.shape_support(shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": support, "ok": False}
    if support.startswith("skip"):
        rec.update(ok=True, skipped=True, reason=support)
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: {support}")
        return _dump(rec, out_dir)

    mesh = make_production_mesh(multi_pod=multi_pod)
    builder = BUILDERS[support]
    t0 = time.monotonic()
    try:
        jitted, args, extra = builder(spec, shape, mesh)
        with mesh:
            lowered = jitted.lower(*args)
            t_lower = time.monotonic() - t0
            t1 = time.monotonic()
            compiled = lowered.compile()
            t_compile = time.monotonic() - t1
        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        stats = analyze(compiled.as_text())
        n_chips = int(np.prod(list(mesh.shape.values())))
        llm_cfg = spec.llm_cfg
        mode = support
        tokens = shape.global_batch * (1 if mode == "decode" else shape.seq_len)
        n_active = llm_cfg.active_param_count()
        if spec.is_mllm and mode != "decode":
            n_active += spec.desc.encoder.param_count()
        # 6·N·D for training (fwd+bwd), 2·N·D for inference forward
        model_fl = (6.0 if mode == "train" else 2.0) * n_active * tokens
        rec.update(
            ok=True, skipped=False,
            n_chips=n_chips,
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            memory={
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "code_bytes": ma.generated_code_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
                "peak_per_chip": ma.argument_size_in_bytes
                + ma.output_size_in_bytes + ma.temp_size_in_bytes
                - ma.alias_size_in_bytes,
            },
            xla_cost={"flops": ca.get("flops", 0.0),
                      "bytes_accessed": ca.get("bytes accessed", 0.0)},
            hlo=stats.as_dict(),
            model_flops=model_fl,
            tokens=tokens,
            params=spec.desc.param_count(),
            active_params=(llm_cfg.active_param_count()
                           + (spec.desc.encoder.param_count()
                              if spec.is_mllm else 0)),
            **extra,
        )
        fits = rec["memory"]["peak_per_chip"] <= MEM_CAP_BYTES
        rec["fits_16gb"] = bool(fits)
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: OK "
                  f"compile={t_compile:.1f}s "
                  f"peak={rec['memory']['peak_per_chip']/1e9:.2f}GB "
                  f"flops/chip={stats.flops:.3e} "
                  f"coll={stats.total_collective_bytes:.3e}B")
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: FAIL {e}")
    return _dump(rec, out_dir)


def _dump(rec: dict, out_dir: Optional[str]) -> dict:
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fn = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
        with open(os.path.join(out_dir, fn), "w") as f:
            json.dump(rec, f, indent=1, default=float)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    args = ap.parse_args()

    combos = []
    archs = ASSIGNED if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = [False, True] if (args.all or args.both_meshes) \
        else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                combos.append((a, s, mp))
    failures = 0
    for a, s, mp in combos:
        rec = run_one(a, s, mp, args.out)
        failures += 0 if rec["ok"] else 1
    print(f"[dryrun] done: {len(combos)} combos, {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
