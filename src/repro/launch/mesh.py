"""Production meshes (TPU v5e target).

Defined as FUNCTIONS so importing this module never touches jax device
state.  The dry-run sets XLA_FLAGS for 512 host devices *before* any jax
import; tests and benchmarks see the default single device.
"""
from __future__ import annotations

import jax


def compat_make_mesh(shape, axes, devices=None):
    """jax.make_mesh across jax versions: `axis_types` (and
    `jax.sharding.AxisType`) only exist in newer releases; older ones
    default to Auto axes anyway.  `devices` restricts the mesh to a subset
    of the local devices (a re-planned θ* rarely uses all of them)."""
    kw = {} if devices is None else {"devices": devices}
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(shape, axes,
                             axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
                             **kw)
    return jax.make_mesh(shape, axes, **kw)


def host_groups(devices, per_host: int):
    """Partition a flat device list into contiguous emulated "hosts" of
    ``per_host`` devices each (the roster `repro.launch.fleet.FleetManager`
    owns).  Raises on a ragged split — every host must field the same
    device count or per-host data shards stop being comparable."""
    devices = list(devices)
    if per_host < 1 or len(devices) % per_host:
        raise ValueError(
            f"{len(devices)} devices do not split into hosts of {per_host}")
    return [devices[i:i + per_host]
            for i in range(0, len(devices), per_host)]


def serve_device_pools(n_prefill: int, n_decode: int, devices=None):
    """Assign the serving engine's worker pools to devices (DistTrain-style
    prefill/decode disaggregation).  With enough devices the pools are
    disjoint — the KV handoff is then a genuine device-to-device transfer
    (on an emulated fleet via ``--xla_force_host_platform_device_count``).
    Fewer devices wrap round-robin, degrading gracefully to same-device
    copies on a single-chip host."""
    devs = list(devices if devices is not None else jax.devices())
    if n_prefill < 1 or n_decode < 1:
        raise ValueError("both pools need at least one worker")
    total = n_prefill + n_decode
    if len(devs) >= total:
        return devs[:n_prefill], devs[n_prefill:total]
    pre = [devs[i % len(devs)] for i in range(n_prefill)]
    dec = [devs[(n_prefill + i) % len(devs)] for i in range(n_decode)]
    return pre, dec


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh over forced host devices (tests / examples)."""
    return compat_make_mesh(shape, axes)


def batch_axes(mesh) -> tuple:
    """Mesh axes that shard the batch by default: pod (if present) + data."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def model_axes(mesh) -> tuple:
    return ("model",) if "model" in mesh.shape else ()
