"""Elastic multi-host execution on emulated fleets.

Everything before this module ran single-host: `clamped_plan_mesh` exists
precisely to paper over a plan whose chip count exceeds the local device
count.  This module supplies the missing control-plane piece — **fleet
membership** — on *emulated* fleets: launch with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the
HomebrewNLP-Jax trick) and one process exposes N host devices, which
`FleetManager` partitions into emulated hosts of ``devices_per_host``
devices each.  Three pieces:

  * ``FleetManager`` — owns the host roster and the mesh bring-up over it:
    ``plan_mesh(plan)`` builds a `ParallelismPlan`'s ``(data, stage,
    model)`` mesh over the *alive* devices (exact when the roster has
    capacity, divisor-aware clamp otherwise — see `fleet_plan_mesh`), and
    ``cluster_spec()`` derives the roster-aware `ClusterSpec` the
    parallelism search re-plans against after a membership change.
    ``join`` / ``leave`` / ``fail`` mutate the roster and queue
    `MembershipEvent`s for the controller (`RuntimeController.poll_fleet`)
    to drain at the next global-batch boundary.
  * ``fleet_plan_mesh`` — the roster-aware mesh factory.  Unlike
    `clamped_plan_mesh`'s ``min()`` clamp, each axis is cut to its largest
    *divisor* that fits, so a stage axis always divides the restacked
    leading dim of stage-stacked params — routing reshards through the
    fleet never silently replicates a pytree a narrower-but-divisible
    stage axis could shard.
  * ``FaultInjector`` — the test/benchmark hook: a deterministic
    ``{step: [(action, host_id), ...]}`` schedule applied by the training
    loop (``on_step(k)``), so kill/revive sequences are reproducible and
    `tests/test_fleet.py` can pin recovery invariants (bit-identical
    `pipeline_forward` outputs across roster transitions, exactly-once
    data delivery, checkpoint-free resume).

Recovery itself lives in `repro.runtime.controller`: on membership events
the controller re-runs the parallelism search for the new roster's chip
count, reshards the live (params, opt) pytree through the
`repro.launch.reshard.ParamSwapper` path onto `FleetManager.plan_mesh`,
and resumes without a checkpoint; a failed reshard or an infeasible
search degrades to the surviving roster instead of crashing
(docs/fleet.md).

Hosts are *emulated*: "devices" are opaque objects (real `jax.Device`s in
a forced-host-count process; anything hashable in roster-only tests), so
the membership machinery runs on the default single device too.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, replace
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from repro.core.optimizer.space import ClusterSpec, ParallelismPlan

# Membership event kinds: "leave" is a graceful departure (drained at a
# batch boundary), "fail" a crash (the in-flight step must be aborted and
# its data shards requeued — repro.data.host_shard), "join" a (re)arrival.
EVENT_KINDS = ("join", "leave", "fail")


@dataclass
class FleetHost:
    """One emulated host: a contiguous slice of the local devices."""

    host_id: int
    devices: tuple
    alive: bool = True


@dataclass(frozen=True)
class MembershipEvent:
    """One roster transition, queued for the controller to drain."""

    kind: str                   # "join" | "leave" | "fail"
    host_id: int
    step: int = -1              # training step the event fired at (-1: n/a)
    n_alive_after: int = 0      # hosts alive once the event applied


def largest_divisor_leq(n: int, limit: int) -> int:
    """Largest divisor of ``n`` that is <= ``limit`` (>= 1).

    >>> largest_divisor_leq(8, 5)
    4
    >>> largest_divisor_leq(6, 4)
    3
    >>> largest_divisor_leq(7, 3)
    1
    """
    for d in range(min(int(n), max(int(limit), 1)), 1, -1):
        if n % d == 0:
            return d
    return 1


def fleet_plan_mesh(plan: ParallelismPlan, devices: Sequence):
    """Plan-implied mesh over a host roster's devices.

    Exact ``(dp, pp, tp)`` over the first ``plan.llm.chips`` devices when
    the roster has capacity; otherwise every axis is clamped to its
    largest *divisor* that fits (tp first, then pp, then dp).  The divisor
    constraint is the point: `clamped_plan_mesh`'s ``min()`` clamp can
    produce a stage axis that does not divide the plan's PP (pp=4 on 3
    devices -> stage 3), which forces `reshard_params` to silently
    replicate stage-stacked leaves — a 2-wide stage axis would have
    sharded them.  Routing mesh bring-up through the fleet keeps stage
    sharding whenever *any* divisor of PP fits the surviving roster.
    """
    devices = list(devices)
    n = len(devices)
    if n == 0:
        raise ValueError("fleet mesh over an empty roster")
    # local import: reshard imports space/executor, not the other way round
    from repro.launch.reshard import PLAN_AXES
    from repro.launch.mesh import compat_make_mesh

    mp = plan.llm
    if mp.chips <= n:
        return compat_make_mesh((mp.dp, mp.pp, mp.tp), PLAN_AXES,
                                devices=devices[:mp.chips])
    tp = largest_divisor_leq(mp.tp, n)
    pp = largest_divisor_leq(mp.pp, max(n // tp, 1))
    dp = largest_divisor_leq(mp.dp, max(n // (tp * pp), 1))
    return compat_make_mesh((dp, pp, tp), PLAN_AXES,
                            devices=devices[:dp * pp * tp])


class FleetManager:
    """Host roster + mesh bring-up for an emulated fleet.

    >>> fm = FleetManager(devices=list("abcdefgh"), devices_per_host=2)
    >>> fm.n_hosts, fm.n_alive, fm.n_chips
    (4, 4, 8)
    >>> _ = fm.fail(1, step=3)
    >>> fm.n_chips, [h.host_id for h in fm.alive]
    (6, [0, 2, 3])
    >>> fm.devices()
    ['a', 'b', 'e', 'f', 'g', 'h']
    >>> [ev.kind for ev in fm.poll_events()]
    ['fail']
    >>> _ = fm.join(1)
    >>> fm.n_chips
    8
    """

    def __init__(self, devices: Optional[Sequence] = None, *,
                 devices_per_host: int = 1,
                 n_hosts: Optional[int] = None):
        if devices is None:
            import jax
            devices = jax.devices()
        devices = list(devices)
        if n_hosts is not None:
            if n_hosts < 1 or len(devices) % n_hosts:
                raise ValueError(
                    f"{len(devices)} devices do not split into "
                    f"{n_hosts} equal hosts")
            devices_per_host = len(devices) // n_hosts
        from repro.launch.mesh import host_groups
        self.devices_per_host = devices_per_host
        self.hosts: List[FleetHost] = [
            FleetHost(i, tuple(group))
            for i, group in enumerate(host_groups(devices, devices_per_host))]
        self._events: Deque[MembershipEvent] = deque()
        self.history: List[MembershipEvent] = []

    # ------------------------------------------------------------------ #
    @property
    def n_hosts(self) -> int:
        return len(self.hosts)

    @property
    def alive(self) -> List[FleetHost]:
        return [h for h in self.hosts if h.alive]

    @property
    def n_alive(self) -> int:
        return len(self.alive)

    def alive_ids(self) -> List[int]:
        return [h.host_id for h in self.alive]

    def devices(self) -> list:
        """Devices of the alive hosts, in host order — the roster every
        mesh is brought up over."""
        return [d for h in self.alive for d in h.devices]

    @property
    def n_chips(self) -> int:
        return len(self.devices())

    def host(self, host_id: int) -> FleetHost:
        for h in self.hosts:
            if h.host_id == host_id:
                return h
        raise KeyError(f"no host {host_id} in the fleet")

    # ------------------------------------------------------------------ #
    def _transition(self, kind: str, host_id: int, step: int,
                    alive: bool) -> MembershipEvent:
        h = self.host(host_id)
        if h.alive == alive:
            state = "alive" if alive else "down"
            raise ValueError(f"host {host_id} is already {state}")
        h.alive = alive
        ev = MembershipEvent(kind, host_id, step, self.n_alive)
        self._events.append(ev)
        self.history.append(ev)
        return ev

    def leave(self, host_id: int, step: int = -1) -> MembershipEvent:
        """Graceful departure (the host drains at a batch boundary)."""
        return self._transition("leave", host_id, step, alive=False)

    def fail(self, host_id: int, step: int = -1) -> MembershipEvent:
        """Crash: the roster effect of `leave`, but consumers must treat
        the in-flight step as lost (abort + requeue its data shards)."""
        return self._transition("fail", host_id, step, alive=False)

    def join(self, host_id: int, step: int = -1) -> MembershipEvent:
        """(Re)arrival of a down host."""
        return self._transition("join", host_id, step, alive=True)

    def poll_events(self) -> List[MembershipEvent]:
        """Drain queued membership events (controller: once per batch
        boundary).  ``history`` keeps the full record."""
        out = list(self._events)
        self._events.clear()
        return out

    # ------------------------------------------------------------------ #
    def plan_mesh(self, plan: ParallelismPlan):
        """Mesh bring-up over the alive roster (`fleet_plan_mesh`).  Pass
        as ``ParamSwapper(mesh_factory=fleet.plan_mesh)`` so physical
        reshards always target the surviving devices."""
        return fleet_plan_mesh(plan, self.devices())

    def cluster_spec(self, template: Optional[ClusterSpec] = None) -> ClusterSpec:
        """Roster-aware `ClusterSpec`: ``n_chips`` tracks the alive
        devices, ``chips_per_node`` the per-host TP domain.  ``template``
        (e.g. the engine's original spec) supplies memory and naming."""
        if template is not None:
            return replace(template, n_chips=self.n_chips,
                           chips_per_node=min(template.chips_per_node,
                                              max(self.devices_per_host, 1)))
        return ClusterSpec(n_chips=self.n_chips,
                           chips_per_node=self.devices_per_host,
                           name="emulated-fleet")

    def partition_items(self, items: Sequence) -> Dict[int, list]:
        """Per-host data shard of one global batch (round-robin over the
        alive roster; `repro.data.host_shard.partition_by_host`)."""
        from repro.data.host_shard import partition_by_host
        return partition_by_host(items, self.alive_ids())


class FaultInjector:
    """Deterministic kill/revive schedule driven by the training loop.

    ``schedule`` maps a step index to the membership actions fired when
    the loop reaches it: ``{6: [("fail", 3)], 12: [("join", 3)]}``.
    The loop calls ``on_step(k)`` once per step *before* drawing data, so
    a killed host's shard is requeued before the next draw partitions
    over the survivors.

    >>> fm = FleetManager(devices=list("abcd"), devices_per_host=1)
    >>> inj = FaultInjector(fm, {2: [("fail", 0)], 5: [("join", 0)]})
    >>> [len(inj.on_step(k)) for k in range(6)]
    [0, 0, 1, 0, 0, 1]
    >>> [ev.kind for ev in inj.fired]
    ['fail', 'join']
    """

    def __init__(self, fleet: FleetManager,
                 schedule: Dict[int, List[Tuple[str, int]]]):
        for step, actions in schedule.items():
            for action, _host in actions:
                if action not in EVENT_KINDS:
                    raise ValueError(f"unknown action {action!r} at step "
                                     f"{step}; expected one of {EVENT_KINDS}")
        self.fleet = fleet
        self.schedule = {int(k): list(v) for k, v in schedule.items()}
        self.fired: List[MembershipEvent] = []

    def on_step(self, step: int) -> List[MembershipEvent]:
        evs = [getattr(self.fleet, action)(host_id, step=step)
               for action, host_id in self.schedule.get(int(step), [])]
        self.fired.extend(evs)
        return evs
