"""Static analyzer for compiled HLO text (§Roofline measurement backbone).

``compiled.cost_analysis()`` counts every `while` body ONCE, which massively
undercounts programs that scan over layers or sequence chunks.  This module
parses ``compiled.as_text()`` (the post-SPMD, per-device module), recovers
while-loop trip counts from their condition computations, and accumulates:

  * flops             — dot/convolution FLOPs × execution multiplicity
  * hbm_bytes         — Σ (operand + result bytes) of top-level ops
                        (post-fusion: each op's operands/results cross HBM;
                        fusion-internal ops are excluded)
  * collective_bytes  — Σ operand bytes of all-gather / all-reduce /
                        reduce-scatter / all-to-all / collective-permute,
                        with multiplicity
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# shape group is lazy: it grows until the first '<word>(' — the opcode call.
# Tuple shapes may contain '/*index=N*/' comments but no parentheses, so the
# first parenthesis after '=' belongs to the opcode's operand list.
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def shape_bytes(shape_str: str) -> int:
    """Bytes of 'f32[4,8]{...}' or tuple '(f32[2], bf16[4])'."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def shape_dims(shape_str: str) -> Tuple[str, List[int]]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return "", []
    dims = [int(d) for d in m.group(2).split(",") if d] if m.group(2) else []
    return m.group(1), dims


@dataclass
class HloOp:
    name: str
    shape: str
    opcode: str
    rest: str                        # operands + attributes text
    operand_names: List[str] = field(default_factory=list)


@dataclass
class HloComputation:
    name: str
    is_entry: bool
    ops: List[HloOp] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)   # op name -> shape


_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def parse_module(text: str) -> Tuple[Dict[str, HloComputation], Optional[str]]:
    comps: Dict[str, HloComputation] = {}
    entry: Optional[str] = None
    cur: Optional[HloComputation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line.strip()) if "{" in line else None
            if m and "->" in line:
                cur = HloComputation(m.group(2), bool(m.group(1)))
                continue
        else:
            stripped = line.strip()
            if stripped == "}" or stripped.startswith("} "):
                comps[cur.name] = cur
                if cur.is_entry:
                    entry = cur.name
                cur = None
                continue
            m = _OP_RE.match(line)
            if m:
                name, shape, opcode, rest = m.groups()
                # operands: %refs before the first attribute keyword
                args = rest.split("),", 1)[0]
                operands = _OPERAND_RE.findall(args)
                op = HloOp(name, shape, opcode, rest, operands)
                cur.ops.append(op)
                cur.shapes[name] = shape
    return comps, entry


_ATTR_COMP_RE = {
    "body": re.compile(r"body=%?([\w.\-]+)"),
    "condition": re.compile(r"condition=%?([\w.\-]+)"),
    "calls": re.compile(r"calls=%?([\w.\-]+)"),
    "to_apply": re.compile(r"to_apply=%?([\w.\-]+)"),
    "branches": re.compile(r"branch_computations={([^}]*)}"),
}
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims={([\d,]*)}")
_BATCH_RE = re.compile(r"lhs_batch_dims={([\d,]*)}")


def _trip_count(cond: HloComputation) -> int:
    """Largest integer constant in a while condition ≈ trip count."""
    best = 1
    for op in cond.ops:
        for c in _CONST_RE.findall(op.rest):
            best = max(best, int(c))
        if op.opcode == "constant":
            m = re.search(r"constant\((\d+)\)", f"constant({op.rest}")
    return best


def _dot_flops(op: HloOp, comp: HloComputation) -> float:
    _, out_dims = shape_dims(op.shape)
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    k = 1
    m = _CONTRACT_RE.search(op.rest)
    if m and op.operand_names:
        lhs_shape = comp.shapes.get(op.operand_names[0], "")
        _, lhs_dims = shape_dims(lhs_shape)
        for idx in m.group(1).split(","):
            if idx and int(idx) < len(lhs_dims):
                k *= lhs_dims[int(idx)]
    return 2.0 * out_elems * k


@dataclass
class HloStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    collective_counts: Dict[str, int] = field(default_factory=lambda: defaultdict(int))
    while_trips: Dict[str, int] = field(default_factory=dict)

    @property
    def total_collective_bytes(self) -> float:
        return float(sum(self.collective_bytes.values()))

    def as_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": dict(self.collective_bytes),
            "collective_counts": dict(self.collective_counts),
            "total_collective_bytes": self.total_collective_bytes,
            "while_trips": self.while_trips,
        }


_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "call", "conditional", "after-all", "partition-id",
    "replica-id", "iota",
}


def analyze(text: str) -> HloStats:
    comps, entry = parse_module(text)
    stats = HloStats()
    if entry is None:
        return stats

    def visit(comp_name: str, mult: float, depth: int = 0,
              count_bytes: bool = True):
        comp = comps.get(comp_name)
        if comp is None or depth > 32:
            return
        for op in comp.ops:
            oc = op.opcode
            if oc == "fusion":
                # fused ops stay on-chip: count the fusion's own operand/
                # result bytes (below), but recurse for FLOPs only.
                m = _ATTR_COMP_RE["calls"].search(op.rest)
                if m:
                    visit(m.group(1), mult, depth + 1, count_bytes=False)
            if oc == "while":
                cond_m = _ATTR_COMP_RE["condition"].search(op.rest)
                body_m = _ATTR_COMP_RE["body"].search(op.rest)
                tc = _TRIP_RE.search(op.rest)
                if tc:
                    trips = int(tc.group(1))
                elif cond_m and cond_m.group(1) in comps:
                    trips = _trip_count(comps[cond_m.group(1)])
                else:
                    trips = 1
                stats.while_trips[op.name] = trips
                if body_m:
                    visit(body_m.group(1), mult * trips, depth + 1)
                continue
            if oc in ("call",):
                m = _ATTR_COMP_RE["to_apply"].search(op.rest)
                if m:
                    visit(m.group(1), mult, depth + 1)
                continue
            if oc == "conditional":
                m = _ATTR_COMP_RE["branches"].search(op.rest)
                if m:
                    for br in _OPERAND_RE.findall(m.group(1)):
                        visit(br, mult, depth + 1)
                continue
            # ---- leaf op accounting -------------------------------------
            if oc == "dot":
                stats.flops += mult * _dot_flops(op, comp)
            elif oc == "convolution":
                # rough: 2 * out elems * (in_ch * kernel) — fall back to
                # 2*out*k from contracting dims if present, else skip
                stats.flops += mult * _dot_flops(op, comp)
            if oc in COLLECTIVES or any(oc.startswith(c) for c in COLLECTIVES):
                kind = next(c for c in COLLECTIVES if oc.startswith(c))
                operand_bytes = sum(
                    shape_bytes(comp.shapes.get(o, "")) for o in op.operand_names)
                if operand_bytes == 0:
                    operand_bytes = shape_bytes(op.shape)
                stats.collective_bytes[kind] += mult * operand_bytes
                stats.collective_counts[kind] += int(mult)
            if oc in _SKIP_BYTES_OPS or not count_bytes:
                continue
            operand_bytes = sum(
                shape_bytes(comp.shapes.get(o, "")) for o in op.operand_names)
            stats.hbm_bytes += mult * (operand_bytes + shape_bytes(op.shape))
        return

    visit(entry, 1.0)
    return stats
