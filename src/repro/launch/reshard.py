"""Physical plan hot-swap: re-lay-out parameters on device for a
re-planned θ*.

`RuntimeController.maybe_swap()` changes the *logical* bucket structure
the Online Scheduler balances against; this module supplies the *physical*
half — without it, device arrays stay sharded for the stale plan and the
swapped θ* is a fiction.  Three pieces:

  * ``plan_mesh(plan)`` — the ``(data, stage, model)`` mesh a
    `ParallelismPlan`'s LLM parallelism implies, built via
    `launch.mesh.compat_make_mesh` over a prefix of the local devices.
  * ``reshard_params(params, old_plan, new_plan)`` — re-stack
    stage-stacked leaves for the new PP degree (generalized
    `executor.stack_stage_params`), then `jax.device_put` onto the new
    mesh's `NamedSharding`s with buffer donation, so the old and new
    layouts are never resident together.  Returns the new params plus a
    `ReshardReport` (bytes moved, elapsed seconds, old/new plan tuples).
  * ``ParamSwapper`` — the controller-facing hook: owns get/set callbacks
    into the training loop's live param pytree, estimates transition cost
    (measured history first, bytes/bandwidth model otherwise) so
    `maybe_swap()` can gate a swap on amortized reshard cost, and performs
    the re-layout at the global-batch boundary.

Layout reconfiguration is *not* free (DistTrain, arXiv:2408.04275): the
swap decision must weigh measured/estimated reshard time against the
predicted per-batch makespan advantage over a horizon — the gate lives in
`repro.runtime.controller`, the cost model here.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, List, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.optimizer.space import ParallelismPlan
from repro.core.pipeline.executor import stack_stage_params
from repro.launch.mesh import compat_make_mesh

# Axis convention for plan-implied meshes.  `pipeline_forward` shards
# stage-stacked leaves over "stage"; "data"/"model" replicate them.
PLAN_AXES = ("data", "stage", "model")

# Default cost-model constants for `estimate_reshard_s`: aggregate
# device-to-device bandwidth (ICI-ish for a v5e slice; the measured-report
# path replaces this as soon as one real swap has happened) and a fixed
# dispatch/compile latency floor per transition.
DEFAULT_BANDWIDTH_BYTES_PER_S = 1e11
DEFAULT_LATENCY_S = 5e-3


@dataclass(frozen=True)
class ReshardReport:
    """What one physical swap actually did (trace/metrics payload)."""

    old_plan: tuple                # ParallelismPlan.as_tuple() before
    new_plan: tuple                # ... and after
    bytes_moved: int               # bytes placed onto a new layout
    bytes_total: int               # total param bytes considered
    elapsed_s: float               # wall time incl. blocking on transfers
    n_leaves: int
    restacked: bool                # stage leaves re-partitioned for new PP


def plan_mesh(plan: ParallelismPlan, *, devices=None) -> Mesh:
    """Mesh implied by ``plan.llm``: shape (dp, pp, tp), axes PLAN_AXES.

    Uses the first ``dp·pp·tp`` of ``devices`` (default: all local
    devices); raises ``ValueError`` when the plan needs more devices than
    exist — `ParamSwapper.compatible` turns that into a gated swap."""
    mp = plan.llm
    n = mp.dp * mp.pp * mp.tp
    devices = list(jax.devices() if devices is None else devices)
    if n > len(devices):
        raise ValueError(
            f"plan {plan.as_tuple()} needs {n} devices, have {len(devices)}")
    return compat_make_mesh((mp.dp, mp.pp, mp.tp), PLAN_AXES,
                            devices=devices[:n])


def clamped_plan_mesh(plan: ParallelismPlan, *, devices=None) -> Mesh:
    """`plan_mesh` clamped onto however many local devices exist.

    Single-host examples/benchmarks emulate a pod-scale transition with
    the devices they have: each axis is cut to fit (tp first, then pp,
    then dp), preserving the plan's axis *structure* while the device
    count shrinks.  Production launches use `plan_mesh` unclamped."""
    devices = list(jax.devices() if devices is None else devices)
    n = len(devices)
    tp = min(plan.llm.tp, n)
    pp = min(plan.llm.pp, max(n // tp, 1))
    dp = min(plan.llm.dp, max(n // (tp * pp), 1))
    return compat_make_mesh((dp, pp, tp), PLAN_AXES,
                            devices=devices[:dp * pp * tp])


def param_bytes(params) -> int:
    """Total bytes across a param pytree.

    >>> import numpy as np
    >>> param_bytes({"w": np.zeros((4, 8), np.float32),
    ...              "b": np.zeros(8, np.float32)})
    160
    """
    return int(sum(leaf.nbytes for leaf in jax.tree_util.tree_leaves(params)))


def estimate_reshard_s(n_bytes: int, *,
                       bandwidth_bytes_per_s: float = DEFAULT_BANDWIDTH_BYTES_PER_S,
                       latency_s: float = DEFAULT_LATENCY_S) -> float:
    """Transfer-time estimate for moving ``n_bytes`` to a new layout.

    >>> estimate_reshard_s(2 * 10**9, bandwidth_bytes_per_s=1e11,
    ...                    latency_s=0.0)
    0.02
    """
    return n_bytes / bandwidth_bytes_per_s + latency_s


def _stage_stacked(params, pp: int) -> bool:
    leaves = jax.tree_util.tree_leaves(params)
    return bool(leaves) and all(
        leaf.ndim >= 2 and leaf.shape[0] == pp for leaf in leaves)


def _restackable(params, old_pp: int, new_pp: int) -> bool:
    return all((leaf.shape[0] * leaf.shape[1]) % new_pp == 0
               for leaf in jax.tree_util.tree_leaves(params)) \
        if _stage_stacked(params, old_pp) else False


def _supports_donate() -> bool:
    import inspect
    return "donate" in inspect.signature(jax.device_put).parameters


def _any_deleted(params) -> bool:
    return any(getattr(leaf, "is_deleted", lambda: False)()
               for leaf in jax.tree_util.tree_leaves(params))


def reshard_params(params, old_plan: ParallelismPlan,
                   new_plan: ParallelismPlan, *,
                   new_mesh: Optional[Mesh] = None,
                   stage_stacked: Optional[bool] = None,
                   donate: bool = True,
                   mesh_factory: Callable[..., Mesh] = plan_mesh):
    """Re-lay-out ``params`` from ``old_plan``'s layout to ``new_plan``'s.

    Stage-stacked pipeline params (leaves ``(old_pp, L/old_pp, ...)``) are
    re-partitioned to ``(new_pp, L/new_pp, ...)`` and sharded over the new
    mesh's "stage" axis; generic pytrees are replicated onto the new mesh.
    A *schedule-only* transition (same LLM parallelism, different schedule
    family in the widened θ tuple) implies an identical mesh: the
    re-layout degenerates to a no-op placement (``bytes_moved == 0``)
    while the report still records the full old/new plan identities.
    Donation hands the old buffers to the transfer so peak memory stays at
    one copy (double-residency during a swap is exactly the failure mode a
    memory-feasible plan can't afford).

    Returns ``(new_params, ReshardReport)``.
    """
    t0 = time.monotonic()
    old_pp, new_pp = old_plan.llm.pp, new_plan.llm.pp
    if stage_stacked is None:
        # Every leaf shaped (old_pp, layers, ...) reads as stage-stacked —
        # including old_pp == 1, where a (1, L, ...) pytree must still be
        # re-partitioned for a larger new PP.  The heuristic is ambiguous
        # for generic pytrees whose leaves all happen to lead with old_pp;
        # pass stage_stacked explicitly (ParamSwapper always does) when
        # the layout is known.
        stage_stacked = _stage_stacked(params, old_pp)

    restacked = False
    if stage_stacked and old_pp != new_pp:
        if not _restackable(params, old_pp, new_pp):
            raise ValueError(
                f"cannot re-stack stage params from pp={old_pp} to "
                f"pp={new_pp}: layer count not divisible")
        params = stack_stage_params(params, new_pp, from_p=old_pp)
        restacked = True

    if new_mesh is None:
        new_mesh = mesh_factory(new_plan)

    # Stage leaves shard over "stage" only when their leading dim divides
    # the mesh's actual stage-axis size — a clamped emulation mesh can be
    # narrower than the plan's PP (e.g. pp=7 on 4 local devices), where
    # the correct layout is replication, not a device_put failure.
    spec = P()
    if stage_stacked:
        # leading dim is new_pp here: a pp change either restacked or raised
        stage_size = dict(new_mesh.shape).get("stage", 1)
        if new_pp % stage_size == 0:
            spec = P("stage")
    sharding = NamedSharding(new_mesh, spec)

    leaves = jax.tree_util.tree_leaves(params)
    total = int(sum(leaf.nbytes for leaf in leaves))
    moved = int(sum(
        leaf.nbytes for leaf in leaves
        if restacked or not (isinstance(leaf, jax.Array)
                             and getattr(leaf, "sharding", None) == sharding)))

    target = jax.tree_util.tree_map(lambda _: sharding, params)
    if donate and _supports_donate():
        new_params = jax.device_put(params, target, donate=True)
    else:
        new_params = jax.device_put(params, target)
    new_params = jax.block_until_ready(new_params)

    report = ReshardReport(
        old_plan=old_plan.as_tuple(), new_plan=new_plan.as_tuple(),
        bytes_moved=moved, bytes_total=total,
        elapsed_s=time.monotonic() - t0, n_leaves=len(leaves),
        restacked=restacked)
    return new_params, report


class ParamSwapper:
    """Controller hook performing the physical half of a plan hot-swap.

    The training loop owns the live params; the swapper reaches them
    through ``get_params``/``set_params`` callbacks so a swap at the
    global-batch boundary mutates the loop's pytree in place:

        state = {"params": params}
        swapper = ParamSwapper(lambda: state["params"],
                               lambda p: state.update(params=p))
        ctl = engine.runtime(gbs, param_swapper=swapper)

    ``stage_stacked=True`` declares pipeline-stacked leaves (re-partitioned
    across PP transitions; with ``strict=True`` an impossible re-stack
    makes `compatible()` False, which gates the *whole* swap — the logical
    and physical plans never diverge).  ``strict=False`` (emulation mode,
    used by single-host benchmarks) falls back to a plain re-placement
    when the layer count doesn't divide the new PP.
    """

    def __init__(self, get_params: Callable[[], object],
                 set_params: Callable[[object], None], *,
                 stage_stacked: bool = False,
                 strict: bool = True,
                 donate: bool = True,
                 mesh_factory: Callable[..., Mesh] = plan_mesh,
                 bandwidth_bytes_per_s: float = DEFAULT_BANDWIDTH_BYTES_PER_S,
                 latency_s: float = DEFAULT_LATENCY_S):
        self._get = get_params
        self._set = set_params
        self.stage_stacked = stage_stacked
        self.strict = strict
        self.donate = donate
        self.mesh_factory = mesh_factory
        self.bandwidth_bytes_per_s = bandwidth_bytes_per_s
        self.latency_s = latency_s
        self.reports: List[ReshardReport] = []
        # True once a failed donated transfer has consumed the live
        # buffers: the stale layout is gone too, recovery is impossible,
        # and the controller must fail fast instead of training on a
        # deleted pytree.  Pass donate=False for a fully recoverable swap
        # at the price of transient double-residency (docs/resharding.md).
        self.damaged = False

    # ------------------------------------------------------------------ #
    def compatible(self, old_plan: ParallelismPlan,
                   new_plan: ParallelismPlan) -> bool:
        """Can this transition be realized physically?  A False return
        gates the logical swap too (controller policy)."""
        try:
            self.mesh_factory(new_plan)
        except ValueError:
            return False
        if (self.strict and self.stage_stacked
                and old_plan.llm.pp != new_plan.llm.pp):
            return _restackable(self._get(), old_plan.llm.pp,
                                new_plan.llm.pp)
        return True

    def estimate_cost_s(self, old_plan: ParallelismPlan,
                        new_plan: ParallelismPlan) -> float:
        """Predicted reshard wall time for the amortization gate.

        Always sized to the bytes of the transition being priced: once any
        swap has moved real bytes, the configured bandwidth is replaced by
        the *measured* one (Σbytes/Σelapsed over history) — a raw mean of
        past elapsed times would misprice as soon as transitions of
        different magnitudes mix."""
        n_bytes = param_bytes(self._get())
        informative = [(r.bytes_moved, r.elapsed_s) for r in self.reports
                       if r.bytes_moved > 0 and r.elapsed_s > 0]
        bandwidth = self.bandwidth_bytes_per_s
        if informative:
            bandwidth = (sum(b for b, _ in informative)
                         / sum(t for _, t in informative))
        return estimate_reshard_s(n_bytes, bandwidth_bytes_per_s=bandwidth,
                                  latency_s=self.latency_s)

    # ------------------------------------------------------------------ #
    def swap(self, old_plan: ParallelismPlan,
             new_plan: ParallelismPlan) -> ReshardReport:
        params = self._get()
        stacked = self.stage_stacked
        if (stacked and not self.strict
                and not _restackable(params, old_plan.llm.pp,
                                     new_plan.llm.pp)):
            stacked = False          # emulation fallback: re-place only
        try:
            new_params, report = reshard_params(
                params, old_plan, new_plan, stage_stacked=stacked,
                donate=self.donate, mesh_factory=self.mesh_factory)
        except Exception:
            if self.donate and _any_deleted(params):
                self.damaged = True
            raise
        self._set(new_params)
        self.reports.append(report)
        return report

    def refresh(self, plan: ParallelismPlan) -> ReshardReport:
        """Re-place the *same* logical plan onto whatever mesh
        ``mesh_factory`` currently resolves — the elastic-recovery
        primitive: after a host loss, a fleet-backed factory
        (`FleetManager.plan_mesh`) now maps the plan onto the surviving
        devices, so ``refresh`` migrates live params off the dead host
        without a plan change (and without a checkpoint)."""
        return self.swap(plan, plan)

    __call__ = swap
