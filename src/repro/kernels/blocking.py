"""Shared grid-blocking policy for the Pallas kernels.

Every kernel tiles a sequence (or channel) axis into ``block``-sized grid
steps.  The old per-kernel ``_pick`` helper chose the largest *divisor* of
the length ≤ the target — which silently degenerates to block size 1 for
prime lengths (a catastrophic grid blowup: a 127-token packed sequence ran
127 × 127 grid steps instead of 1).  The shared policy here instead pads the
axis up to the next block multiple and lets masking neutralize the tail:

  * attention — padded positions carry segment id ``PAD_SEGMENT`` (−1),
    which can never equal a real segment id (callers use ids ≥ 0), so the
    existing segment mask hides the tail for free; padded query rows are
    zeroed by the ``l > 0`` finalize guard and sliced off.
  * scans — padded steps are identities (mamba: dt = 0 ⇒ decay = 1, no
    input; rwkv: w = 1, k = v = 0 ⇒ state passes through), so the final
    state and all real-position outputs are untouched.

Gradients need no special handling: padding/slicing happen *outside* the
kernels' ``custom_vjp`` boundary with plain ``jnp.pad``/slice, whose
transposes drop the tail cotangents automatically.

>>> pick_block(128, 64)      # divisible: exact tiling, no padding
(64, 128)
>>> pick_block(127, 64)      # prime: pad one step instead of 127 steps
(64, 128)
>>> pick_block(96, 128)      # short axis: single block, no padding
(96, 96)
>>> pick_block(257, 64)      # minimal grid: ceil(257/64) = 5 steps
(64, 320)
"""
from __future__ import annotations

import jax.numpy as jnp

# Reserved segment id for padded positions: real segment ids are ≥ 0
# (0 = packing tail, 1..n = instances), so −1 never matches under the
# ``seg_q == seg_k`` mask.
PAD_SEGMENT = -1


def pick_block(s: int, target: int) -> tuple:
    """Block size and padded length for an axis of length ``s``.

    Returns ``(block, padded)`` with ``block = min(s, target)`` and
    ``padded`` the next multiple of ``block`` ≥ ``s`` — the minimal grid:
    ``padded // block == ceil(s / block)``, never more than one partial
    step of overhead regardless of divisibility.
    """
    b = min(int(s), max(1, int(target)))
    padded = -(-int(s) // b) * b
    return b, padded


def pad_axis(x, padded: int, axis: int, value=0):
    """Pad ``x`` along ``axis`` up to length ``padded`` with ``value``.

    No-op (returns ``x`` unchanged) when the axis already has that length,
    so jit'd callers trace identical programs for divisible shapes.
    """
    n = x.shape[axis]
    if n == padded:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, padded - n)
    return jnp.pad(x, widths, constant_values=value)
