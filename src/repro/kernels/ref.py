"""Pure-jnp oracles for the Pallas kernels (allclose targets).

These re-export the model layers' reference implementations so the kernels
and the models are validated against the *same* math.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.layers.attention import attend_naive as packed_attention_ref
from repro.models.layers.mamba import ssm_scan_xla as mamba_scan_ref
from repro.models.layers.rwkv6 import wkv_scan_xla as rwkv6_scan_ref

__all__ = ["packed_attention_ref", "mamba_scan_ref", "rwkv6_scan_ref"]
