"""Pallas TPU kernels for the framework's compute hot-spots.

Each kernel ships three artifacts:
  <name>.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
  ops.py    — jit'd public wrappers (auto-interpret on CPU)
  ref.py    — pure-jnp oracles used by the allclose test sweeps
"""
