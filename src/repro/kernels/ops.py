"""Public jit'd wrappers for the Pallas kernels.

On CPU (this container) kernels execute with ``interpret=True`` — the kernel
body runs op-by-op in Python, validating the exact TPU program against the
``ref.py`` oracles.  On a real TPU backend ``interpret=False`` compiles the
Mosaic kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.mamba_scan import mamba_scan_bsd
from repro.kernels.packed_flash_attention import packed_flash_attention_bkgsd
from repro.kernels.rwkv6_scan import rwkv6_scan_bhsm


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def packed_flash_attention(q, k, v, *, segment_ids=None, causal=True,
                           window=0, block_q=512, block_k=512):
    """q: (B, S, H, D); k, v: (B, S, KH, D); segment_ids: (B, S) int32.
    Returns (B, S, H, D) — layout-matched to the model's attention layer."""
    B, S, H, D = q.shape
    KH = k.shape[2]
    G = H // KH
    if segment_ids is None:
        segment_ids = jnp.zeros((B, S), jnp.int32)
    # GQA convention: head h attends through kv head h // G — the
    # (B, S, KH, G, D) reshape groups G consecutive query heads per kv head.
    qt = q.reshape(B, S, KH, G, D).transpose(0, 2, 3, 1, 4)  # (B,KH,G,S,D)
    kt = k.transpose(0, 2, 1, 3)                             # (B,KH,S,D)
    vt = v.transpose(0, 2, 1, 3)
    out = packed_flash_attention_bkgsd(
        qt, kt, vt, segment_ids, segment_ids, causal=causal, window=window,
        block_q=block_q, block_k=block_k, interpret=_interpret())
    return out.transpose(0, 3, 1, 2, 4).reshape(B, S, H, D)


def rwkv6_scan(r, k, v, w, u, *, chunk=128):
    """r,k,v,w: (B, S, H, M); u: (H, M). Returns (y (B,S,H,M), state)."""
    rt, kt, vt, wt = (t.transpose(0, 2, 1, 3) for t in (r, k, v, w))
    y, s = rwkv6_scan_bhsm(rt, kt, vt, wt, u, chunk=chunk,
                           interpret=_interpret())
    return y.transpose(0, 2, 1, 3), s


def mamba_scan(u, dt, B_t, C_t, A, D, *, chunk=128, c_blk=512):
    """u, dt: (B,S,di); B_t, C_t: (B,S,N); A: (di,N); D: (di,).
    Returns (y (B,S,di), None) — state hand-off via the XLA path."""
    y = mamba_scan_bsd(u, dt, B_t, C_t, A, D, chunk=chunk, c_blk=c_blk,
                       interpret=_interpret())
    return y, None
