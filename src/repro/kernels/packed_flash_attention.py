"""Packed flash attention — Pallas TPU kernel, forward + custom VJP.

The paper's sequence packing (§3.2.1) requires attention to "process each
original instance separately to maintain causal integrity": these kernels
fuse segment-id masking (packing boundaries), causality and an optional
sliding window into an online-softmax flash attention with explicit VMEM
tiling.

Layout: q is pre-arranged as (B, KH, G, S, D) (G = query groups per KV
head — GQA/MQA-native, so each KV block is loaded once for all G groups),
k/v as (B, KH, S, D).  Grid (B, KH, nq, nk) with the kv axis innermost and
sequential; the online-softmax running max / denominator / accumulator live
in VMEM scratch carried across kv steps.  Default (bq, bk) = (512, 512) —
MXU-aligned multiples of 128 — keeps the working set
    q (G·bq·D) + k,v (2·bk·D) + acc (G·bq·D) + p (G·bq·bk)       [f32]
at a few MiB, inside the 16 MiB v5e VMEM for G ≤ 8, D ≤ 256.

Backward (FlashAttention-2 style, ``docs/kernels.md``): the forward also
emits the per-row log-sum-exp; the backward recomputes the probabilities
p = exp(s − lse) block-by-block from the saved (o, lse) residuals instead
of storing the S² attention matrix, with the delta trick
Δ = rowsum(dout ⊙ o) so ds = p·(dp − Δ)·scale.  Two kernels share the
forward's masking: dq accumulates over kv blocks (same grid orientation as
the forward), dk/dv accumulate over q blocks (grid (B, KH, nk, nq), the q
axis innermost).  Non-multiple sequence lengths are padded to the block
grid (``repro.kernels.blocking``); padded positions carry segment id −1 so
the segment mask hides them, and the pad/slice transposes drop their
cotangents.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.blocking import PAD_SEGMENT, pad_axis, pick_block

NEG_INF = -1e30


def _tile_mask(iq, ik, seg_q, seg_k, *, causal: bool, window: int,
               bq: int, bk: int):
    """Boolean (bq, bk) attend-mask for tile (iq, ik) — the ONE masking
    definition all four kernels (fwd, dq, dkv) share."""
    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), dtype=jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= qpos - kpos < window
    mask &= seg_q[:, None] == seg_k[None, :]
    return mask


def _fwd_kernel(q_ref, k_ref, v_ref, seg_q_ref, seg_k_ref, o_ref, lse_ref,
                m_scr, l_scr, acc_scr, *, scale: float, causal: bool,
                window: int, nk: int, bq: int, bk: int):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)              # (G, bq, D)
    k = k_ref[0, 0].astype(jnp.float32)              # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)              # (bk, D)

    s = jax.lax.dot_general(q, k, (((2,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    mask = _tile_mask(iq, ik, seg_q_ref[0], seg_k_ref[0], causal=causal,
                      window=window, bq=bq, bk=bk)
    s = jnp.where(mask[None], s, NEG_INF)            # (G, bq, bk)

    m_prev = m_scr[...]                              # (G, bq)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    corr = jnp.exp(m_prev - m_new)
    # explicit mask select: on a row masked in every tile m_new stays at
    # NEG_INF and exp(s - m_new) would be exp(0) = 1, silently averaging
    # v; zeroed p keeps l at 0 so the finalize guard emits exact zeros
    p = jnp.where(mask[None], jnp.exp(s - m_new[..., None]), 0.0)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
    pv = jax.lax.dot_general(p, v, (((2,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * corr[..., None] + pv
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_scr[...]
        out = acc_scr[...] / jnp.maximum(l, 1e-30)[..., None]
        out = jnp.where((l > 0)[..., None], out, 0.0)
        o_ref[0, 0] = out.astype(o_ref.dtype)
        m = m_scr[...]
        lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)), NEG_INF)
        lse_ref[0, 0] = lse


def _tile_p_ds(q, k, v, do, lse, delta, mask, *, scale: float):
    """Recompute (p, ds) for one tile from the saved residuals.

    s − lse ≤ 0 for every unmasked entry (lse = m + log l ≥ m), so the exp
    cannot overflow; fully-masked rows have lse = NEG_INF and are zeroed by
    the mask select."""
    s = jax.lax.dot_general(q, k, (((2,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask[None], s, NEG_INF)            # (G, bq, bk)
    p = jnp.exp(s - lse[..., None])
    p = jnp.where(mask[None], p, 0.0)
    dp = jax.lax.dot_general(do, v, (((2,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta[..., None]) * scale
    return p, ds


def _bwd_dq_kernel(q_ref, k_ref, v_ref, seg_q_ref, seg_k_ref, do_ref,
                   lse_ref, delta_ref, dq_ref, dq_scr, *, scale: float,
                   causal: bool, window: int, nk: int, bq: int, bk: int):
    """dq = Σ_j ds_ij · k_j.  Grid (B, KH, nq, nk), kv innermost."""
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        dq_scr[...] = jnp.zeros_like(dq_scr)

    mask = _tile_mask(iq, ik, seg_q_ref[0], seg_k_ref[0], causal=causal,
                      window=window, bq=bq, bk=bk)
    _, ds = _tile_p_ds(q_ref[0, 0].astype(jnp.float32),
                       k_ref[0, 0].astype(jnp.float32),
                       v_ref[0, 0].astype(jnp.float32),
                       do_ref[0, 0].astype(jnp.float32),
                       lse_ref[0, 0], delta_ref[0, 0], mask, scale=scale)
    dq_scr[...] += jax.lax.dot_general(ds, k_ref[0, 0].astype(jnp.float32),
                                       (((2,), (0,)), ((), ())),
                                       preferred_element_type=jnp.float32)

    @pl.when(ik == nk - 1)
    def _finalize():
        dq_ref[0, 0] = dq_scr[...].astype(dq_ref.dtype)


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, seg_q_ref, seg_k_ref, do_ref,
                    lse_ref, delta_ref, dk_ref, dv_ref, dk_scr, dv_scr, *,
                    scale: float, causal: bool, window: int, nq: int,
                    bq: int, bk: int):
    """dk_j = Σ_i ds_ijᵀ q_i, dv_j = Σ_i p_ijᵀ do_i.
    Grid (B, KH, nk, nq), the q axis innermost/sequential."""
    iq = pl.program_id(3)
    ik = pl.program_id(2)

    @pl.when(iq == 0)
    def _init():
        dk_scr[...] = jnp.zeros_like(dk_scr)
        dv_scr[...] = jnp.zeros_like(dv_scr)

    q = q_ref[0, 0].astype(jnp.float32)              # (G, bq, D)
    do = do_ref[0, 0].astype(jnp.float32)
    mask = _tile_mask(iq, ik, seg_q_ref[0], seg_k_ref[0], causal=causal,
                      window=window, bq=bq, bk=bk)
    p, ds = _tile_p_ds(q, k_ref[0, 0].astype(jnp.float32),
                       v_ref[0, 0].astype(jnp.float32), do,
                       lse_ref[0, 0], delta_ref[0, 0], mask, scale=scale)
    # contract the (G, bq) axes: (G,bq,bk) × (G,bq,D) -> (bk, D)
    dv_scr[...] += jax.lax.dot_general(p, do, (((0, 1), (0, 1)), ((), ())),
                                       preferred_element_type=jnp.float32)
    dk_scr[...] += jax.lax.dot_general(ds, q, (((0, 1), (0, 1)), ((), ())),
                                       preferred_element_type=jnp.float32)

    @pl.when(iq == nq - 1)
    def _finalize():
        dk_ref[0, 0] = dk_scr[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_scr[...].astype(dv_ref.dtype)


# --------------------------------------------------------------------------- #
# pallas_call wrappers (shapes already padded to the block grid)
# --------------------------------------------------------------------------- #
def _fwd_call(q, k, v, seg_q, seg_k, causal, window, bq, bk, interpret):
    B, KH, G, Sq, D = q.shape
    Sk = k.shape[2]
    nq, nk = Sq // bq, Sk // bk
    kernel = functools.partial(_fwd_kernel, scale=D ** -0.5, causal=causal,
                               window=window, nk=nk, bq=bq, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=(B, KH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, bq, D), lambda b, h, i, j: (b, h, 0, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, bq), lambda b, h, i, j: (b, i)),
            pl.BlockSpec((1, bk), lambda b, h, i, j: (b, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, G, bq, D), lambda b, h, i, j: (b, h, 0, i, 0)),
            pl.BlockSpec((1, 1, G, bq), lambda b, h, i, j: (b, h, 0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, KH, G, Sq, D), q.dtype),
            jax.ShapeDtypeStruct((B, KH, G, Sq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((G, bq), jnp.float32),
            pltpu.VMEM((G, bq), jnp.float32),
            pltpu.VMEM((G, bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, seg_q, seg_k)


def _bwd_call(q, k, v, seg_q, seg_k, out, lse, dout, causal, window,
              bq, bk, interpret):
    B, KH, G, Sq, D = q.shape
    Sk = k.shape[2]
    nq, nk = Sq // bq, Sk // bk
    scale = D ** -0.5
    do32 = dout.astype(jnp.float32)
    delta = jnp.sum(do32 * out.astype(jnp.float32), axis=-1)  # (B,KH,G,Sq)

    q_spec = pl.BlockSpec((1, 1, G, bq, D), lambda b, h, i, j: (b, h, 0, i, 0))
    row_spec = pl.BlockSpec((1, 1, G, bq), lambda b, h, i, j: (b, h, 0, i))
    kv_spec = pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h, j, 0))
    sq_spec = pl.BlockSpec((1, bq), lambda b, h, i, j: (b, i))
    sk_spec = pl.BlockSpec((1, bk), lambda b, h, i, j: (b, j))

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, scale=scale, causal=causal,
                          window=window, nk=nk, bq=bq, bk=bk),
        grid=(B, KH, nq, nk),
        in_specs=[q_spec, kv_spec, kv_spec, sq_spec, sk_spec, q_spec,
                  row_spec, row_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct((B, KH, G, Sq, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((G, bq, D), jnp.float32)],
        interpret=interpret,
    )(q, k, v, seg_q, seg_k, dout, lse, delta)

    # q axis innermost: same index maps, grid dims (j, i) swapped
    q_spec2 = pl.BlockSpec((1, 1, G, bq, D), lambda b, h, j, i: (b, h, 0, i, 0))
    row_spec2 = pl.BlockSpec((1, 1, G, bq), lambda b, h, j, i: (b, h, 0, i))
    kv_spec2 = pl.BlockSpec((1, 1, bk, D), lambda b, h, j, i: (b, h, j, 0))
    sq_spec2 = pl.BlockSpec((1, bq), lambda b, h, j, i: (b, i))
    sk_spec2 = pl.BlockSpec((1, bk), lambda b, h, j, i: (b, j))
    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, scale=scale, causal=causal,
                          window=window, nq=nq, bq=bq, bk=bk),
        grid=(B, KH, nk, nq),
        in_specs=[q_spec2, kv_spec2, kv_spec2, sq_spec2, sk_spec2, q_spec2,
                  row_spec2, row_spec2],
        out_specs=[kv_spec2, kv_spec2],
        out_shape=[jax.ShapeDtypeStruct((B, KH, Sk, D), k.dtype),
                   jax.ShapeDtypeStruct((B, KH, Sk, D), v.dtype)],
        scratch_shapes=[pltpu.VMEM((bk, D), jnp.float32),
                        pltpu.VMEM((bk, D), jnp.float32)],
        interpret=interpret,
    )(q, k, v, seg_q, seg_k, dout, lse, delta)
    return dq, dk, dv


# --------------------------------------------------------------------------- #
# custom VJP (block sizes are static; shapes arrive pre-padded)
# --------------------------------------------------------------------------- #
@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9))
def _flash(q, k, v, seg_q, seg_k, causal, window, bq, bk, interpret):
    out, _ = _fwd_call(q, k, v, seg_q, seg_k, causal, window, bq, bk,
                       interpret)
    return out


def _flash_fwd_rule(q, k, v, seg_q, seg_k, causal, window, bq, bk, interpret):
    out, lse = _fwd_call(q, k, v, seg_q, seg_k, causal, window, bq, bk,
                         interpret)
    return out, (q, k, v, seg_q, seg_k, out, lse)


def _flash_bwd_rule(causal, window, bq, bk, interpret, res, dout):
    q, k, v, seg_q, seg_k, out, lse = res
    dq, dk, dv = _bwd_call(q, k, v, seg_q, seg_k, out, lse, dout, causal,
                           window, bq, bk, interpret)
    return dq, dk, dv, None, None


_flash.defvjp(_flash_fwd_rule, _flash_bwd_rule)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def packed_flash_attention_bkgsd(q, k, v, seg_q, seg_k, *, causal: bool = True,
                                 window: int = 0, block_q: int = 512,
                                 block_k: int = 512, interpret: bool = False):
    """q: (B, KH, G, Sq, D); k, v: (B, KH, Sk, D); seg_*: (B, S) int32.
    Returns (B, KH, G, Sq, D).  Differentiable in (q, k, v)."""
    B, KH, G, Sq, D = q.shape
    Sk = k.shape[2]
    bq, Sq_p = pick_block(Sq, block_q)
    bk, Sk_p = pick_block(Sk, block_k)
    q = pad_axis(q, Sq_p, axis=3)
    seg_q = pad_axis(seg_q, Sq_p, axis=1, value=PAD_SEGMENT)
    k = pad_axis(k, Sk_p, axis=2)
    v = pad_axis(v, Sk_p, axis=2)
    seg_k = pad_axis(seg_k, Sk_p, axis=1, value=PAD_SEGMENT)
    out = _flash(q, k, v, seg_q, seg_k, causal, window, bq, bk, interpret)
    return out[:, :, :, :Sq]
