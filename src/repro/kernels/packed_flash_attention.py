"""Packed flash attention — Pallas TPU kernel.

The paper's sequence packing (§3.2.1) requires attention to "process each
original instance separately to maintain causal integrity": this kernel
fuses segment-id masking (packing boundaries), causality and an optional
sliding window into an online-softmax flash attention with explicit VMEM
tiling.

Layout: q is pre-arranged as (B, KH, G, S, D) (G = query groups per KV
head — GQA/MQA-native, so each KV block is loaded once for all G groups),
k/v as (B, KH, S, D).  Grid (B, KH, nq, nk) with the kv axis innermost and
sequential; the online-softmax running max / denominator / accumulator live
in VMEM scratch carried across kv steps.  Default (bq, bk) = (512, 512) —
MXU-aligned multiples of 128 — keeps the working set
    q (G·bq·D) + k,v (2·bk·D) + acc (G·bq·D) + p (G·bq·bk)       [f32]
at a few MiB, inside the 16 MiB v5e VMEM for G ≤ 8, D ≤ 256.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, seg_q_ref, seg_k_ref, o_ref,
            m_scr, l_scr, acc_scr, *, scale: float, causal: bool,
            window: int, nk: int, bq: int, bk: int):
    ik = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)              # (G, bq, D)
    k = k_ref[0, 0].astype(jnp.float32)              # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)              # (bk, D)

    s = jax.lax.dot_general(q, k, (((2,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), dtype=jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= qpos - kpos < window
    seg_q = seg_q_ref[0]                             # (bq,)
    seg_k = seg_k_ref[0]                             # (bk,)
    mask &= seg_q[:, None] == seg_k[None, :]
    s = jnp.where(mask[None], s, NEG_INF)            # (G, bq, bk)

    m_prev = m_scr[...]                              # (G, bq)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[..., None])
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
    pv = jax.lax.dot_general(p, v, (((2,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_scr[...] = acc_scr[...] * corr[..., None] + pv
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_scr[...]
        out = acc_scr[...] / jnp.maximum(l, 1e-30)[..., None]
        out = jnp.where((l > 0)[..., None], out, 0.0)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def _pick(s: int, target: int) -> int:
    b = min(s, target)
    while s % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def packed_flash_attention_bkgsd(q, k, v, seg_q, seg_k, *, causal: bool = True,
                                 window: int = 0, block_q: int = 512,
                                 block_k: int = 512, interpret: bool = False):
    """q: (B, KH, G, Sq, D); k, v: (B, KH, Sk, D); seg_*: (B, S) int32.
    Returns (B, KH, G, Sq, D)."""
    B, KH, G, Sq, D = q.shape
    Sk = k.shape[2]
    bq, bk = _pick(Sq, block_q), _pick(Sk, block_k)
    nq, nk = Sq // bq, Sk // bk
    scale = D ** -0.5

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               window=window, nk=nk, bq=bq, bk=bk)
    return pl.pallas_call(
        kernel,
        grid=(B, KH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, bq, D), lambda b, h, i, j: (b, h, 0, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, bq), lambda b, h, i, j: (b, i)),
            pl.BlockSpec((1, bk), lambda b, h, i, j: (b, j)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, bq, D),
                               lambda b, h, i, j: (b, h, 0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KH, G, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, bq), jnp.float32),
            pltpu.VMEM((G, bq), jnp.float32),
            pltpu.VMEM((G, bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, seg_q, seg_k)
