"""RWKV-6 WKV recurrence — chunked Pallas TPU kernel.

    S_t = diag(w_t)·S_{t-1} + k_t v_tᵀ
    y_t = r_t·(S_{t-1} + diag(u)·k_t v_tᵀ)

TPU adaptation: the recurrence is chunked along time.  Grid (B, H, n_chunks)
with the chunk axis innermost/sequential; the (M, M) state lives in VMEM
scratch and crosses chunk iterations without HBM round-trips.  Inside a
chunk the per-step update runs as a fori_loop over rows held in VMEM —
the O(M²) state update is VPU work on an (M, M) tile, M = 64 lanes wide.

Inputs are pre-arranged (B, H, S, M); outputs match.  The final state
(B, H, M, M) is emitted for decode hand-off.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_out_ref, state_scr,
            *, n_chunks: int, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    u = u_ref[0].astype(jnp.float32)                   # (M,)

    def step(t, state):
        r_t = r_ref[0, 0, t].astype(jnp.float32)       # (M,)
        k_t = k_ref[0, 0, t].astype(jnp.float32)
        v_t = v_ref[0, 0, t].astype(jnp.float32)
        w_t = w_ref[0, 0, t].astype(jnp.float32)
        kv = k_t[:, None] * v_t[None, :]               # (M, M)
        y = jnp.sum(r_t[:, None] * (state + u[:, None] * kv), axis=0)
        y_ref[0, 0, t] = y.astype(y_ref.dtype)
        return w_t[:, None] * state + kv

    state = jax.lax.fori_loop(0, chunk, step, state_scr[...])
    state_scr[...] = state

    @pl.when(ic == n_chunks - 1)
    def _emit():
        s_out_ref[0, 0] = state_scr[...]


def _pick(s: int, target: int) -> int:
    b = min(s, target)
    while s % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan_bhsm(r, k, v, w, u, *, chunk: int = 128,
                    interpret: bool = False):
    """r,k,v,w: (B, H, S, M); u: (H, M).
    Returns y: (B, H, S, M), final state (B, H, M, M) f32."""
    B, H, S, M = r.shape
    c = _pick(S, chunk)
    n_chunks = S // c
    kernel = functools.partial(_kernel, n_chunks=n_chunks, chunk=c)
    y, s_final = pl.pallas_call(
        kernel,
        grid=(B, H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, 1, c, M), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, c, M), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, c, M), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, c, M), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, M), lambda b, h, i: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, c, M), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, M, M), lambda b, h, i: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, M), r.dtype),
            jax.ShapeDtypeStruct((B, H, M, M), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((M, M), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)
    return y, s_final
