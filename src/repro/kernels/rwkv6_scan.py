"""RWKV-6 WKV recurrence — chunked Pallas TPU kernel, forward + custom VJP.

    S_t = diag(w_t)·S_{t-1} + k_t v_tᵀ
    y_t = r_t·(S_{t-1} + diag(u)·k_t v_tᵀ)

TPU adaptation: the recurrence is chunked along time.  Grid (B, H, n_chunks)
with the chunk axis innermost/sequential; the (M, M) state lives in VMEM
scratch and crosses chunk iterations without HBM round-trips.  Inside a
chunk the per-step update runs as a fori_loop over rows held in VMEM —
the O(M²) state update is VPU work on an (M, M) tile, M = 64 lanes wide.

Inputs are pre-arranged (B, H, S, M); outputs match.  The final state
(B, H, M, M) is emitted for decode hand-off.

Backward (``docs/kernels.md``): the forward also emits each chunk's
*initial* state (B, H, n_chunks, M, M); the backward walks chunks in
reverse (index maps close over ``n_chunks − 1 − i``), replays the chunk
into a (chunk, M, M) VMEM history of pre-states S_{t-1}, then runs the
state-adjoint recurrence

    G_{t-1} = diag(w_t)·G_t + r_t ŷ_tᵀ        (G carried across chunks)

per step t descending — the final-state cotangent seeds G at the last
chunk.  dr/dk/dv/dw are written in place; du is emitted as a per-batch
partial (accumulating an output block is only safe across consecutive
innermost-grid revisits) and summed over batch outside the kernel.
Non-multiple lengths are padded (``repro.kernels.blocking``) with
w = 1, r = k = v = 0, so a padded step passes the state through untouched
and the emitted final state stays exact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.blocking import pad_axis, pick_block


def _fwd_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, y_ref, s_out_ref,
                sinit_ref, state_scr, *, n_chunks: int, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    sinit_ref[0, 0, 0] = state_scr[...]                # this chunk's S_{-1}

    u = u_ref[0].astype(jnp.float32)                   # (M,)

    def step(t, state):
        r_t = r_ref[0, 0, t].astype(jnp.float32)       # (M,)
        k_t = k_ref[0, 0, t].astype(jnp.float32)
        v_t = v_ref[0, 0, t].astype(jnp.float32)
        w_t = w_ref[0, 0, t].astype(jnp.float32)
        kv = k_t[:, None] * v_t[None, :]               # (M, M)
        y = jnp.sum(r_t[:, None] * (state + u[:, None] * kv), axis=0)
        y_ref[0, 0, t] = y.astype(y_ref.dtype)
        return w_t[:, None] * state + kv

    state = jax.lax.fori_loop(0, chunk, step, state_scr[...])
    state_scr[...] = state

    @pl.when(ic == n_chunks - 1)
    def _emit():
        s_out_ref[0, 0] = state_scr[...]


def _bwd_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, sinit_ref, dy_ref, ds_ref,
                dr_ref, dk_ref, dv_ref, dw_ref, du_ref, g_scr, hist_scr,
                *, chunk: int):
    """One reversed-order chunk of the WKV adjoint (see module docstring).

    hist_scr[t] holds the replayed pre-state S_{t-1}; g_scr carries the
    state adjoint G across (reversed) chunk iterations, seeded with the
    final-state cotangent at the last chunk."""
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():                                       # last chunk first
        g_scr[...] = ds_ref[0, 0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)                   # (M,)

    def replay(t, state):
        hist_scr[t] = state
        k_t = k_ref[0, 0, t].astype(jnp.float32)
        v_t = v_ref[0, 0, t].astype(jnp.float32)
        w_t = w_ref[0, 0, t].astype(jnp.float32)
        return w_t[:, None] * state + k_t[:, None] * v_t[None, :]

    jax.lax.fori_loop(0, chunk, replay, sinit_ref[0, 0, 0].astype(jnp.float32))

    def bstep(s, carry):
        g, du_acc = carry
        t = chunk - 1 - s
        r_t = r_ref[0, 0, t].astype(jnp.float32)
        k_t = k_ref[0, 0, t].astype(jnp.float32)
        v_t = v_ref[0, 0, t].astype(jnp.float32)
        w_t = w_ref[0, 0, t].astype(jnp.float32)
        dy_t = dy_ref[0, 0, t].astype(jnp.float32)     # (M,)
        s_prev = hist_scr[t]                           # (M, M)
        vdy = jnp.sum(v_t * dy_t)                      # scalar ⟨v_t, ŷ_t⟩
        dw_ref[0, 0, t] = jnp.sum(g * s_prev, axis=1)
        dk_ref[0, 0, t] = jnp.sum(g * v_t[None, :], axis=1) + u * r_t * vdy
        dv_ref[0, 0, t] = (jnp.sum(g * k_t[:, None], axis=0)
                           + jnp.sum(r_t * u * k_t) * dy_t)
        dr_ref[0, 0, t] = (jnp.sum(s_prev * dy_t[None, :], axis=1)
                           + u * k_t * vdy)
        du_acc = du_acc + r_t * k_t * vdy
        g = w_t[:, None] * g + r_t[:, None] * dy_t[None, :]
        return g, du_acc

    g, du_acc = jax.lax.fori_loop(
        0, chunk, bstep, (g_scr[...], jnp.zeros_like(u)))
    g_scr[...] = g

    @pl.when(ic == 0)
    def _first():
        du_ref[0, 0] = du_acc

    @pl.when(ic > 0)
    def _rest():
        du_ref[0, 0] += du_acc


def _fwd_call(r, k, v, w, u, c, interpret):
    B, H, S, M = r.shape
    n_chunks = S // c
    seq_spec = pl.BlockSpec((1, 1, c, M), lambda b, h, i: (b, h, i, 0))
    return pl.pallas_call(
        functools.partial(_fwd_kernel, n_chunks=n_chunks, chunk=c),
        grid=(B, H, n_chunks),
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec,
                  pl.BlockSpec((1, M), lambda b, h, i: (h, 0))],
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, 1, M, M), lambda b, h, i: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, 1, M, M), lambda b, h, i: (b, h, i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, M), r.dtype),
            jax.ShapeDtypeStruct((B, H, M, M), jnp.float32),
            jax.ShapeDtypeStruct((B, H, n_chunks, M, M), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((M, M), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u)


def _bwd_call(r, k, v, w, u, s_init, dy, ds, c, interpret):
    B, H, S, M = r.shape
    n_chunks = S // c
    rev = n_chunks - 1                                 # reversed chunk walk
    f32 = jnp.float32
    seq_spec = pl.BlockSpec((1, 1, c, M), lambda b, h, i: (b, h, rev - i, 0))
    return pl.pallas_call(
        functools.partial(_bwd_kernel, chunk=c),
        grid=(B, H, n_chunks),
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, M), lambda b, h, i: (h, 0)),
            pl.BlockSpec((1, 1, 1, M, M), lambda b, h, i: (b, h, rev - i, 0, 0)),
            seq_spec,
            pl.BlockSpec((1, 1, M, M), lambda b, h, i: (b, h, 0, 0)),
        ],
        out_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, 1, M), lambda b, h, i: (b, h, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, M), f32),   # dr
            jax.ShapeDtypeStruct((B, H, S, M), f32),   # dk
            jax.ShapeDtypeStruct((B, H, S, M), f32),   # dv
            jax.ShapeDtypeStruct((B, H, S, M), f32),   # dw
            jax.ShapeDtypeStruct((B, H, M), f32),      # du partial (per-B)
        ],
        scratch_shapes=[pltpu.VMEM((M, M), jnp.float32),
                        pltpu.VMEM((c, M, M), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s_init, dy, ds)


@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6))
def _scan(r, k, v, w, u, c, interpret):
    y, s_final, _ = _fwd_call(r, k, v, w, u, c, interpret)
    return y, s_final


def _scan_fwd_rule(r, k, v, w, u, c, interpret):
    y, s_final, s_init = _fwd_call(r, k, v, w, u, c, interpret)
    return (y, s_final), (r, k, v, w, u, s_init)


def _scan_bwd_rule(c, interpret, res, cts):
    r, k, v, w, u, s_init = res
    dy, ds = cts
    dr, dk, dv, dw, du_p = _bwd_call(r, k, v, w, u, s_init, dy, ds, c,
                                     interpret)
    return (dr.astype(r.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
            dw.astype(w.dtype), jnp.sum(du_p, axis=0).astype(u.dtype))


_scan.defvjp(_scan_fwd_rule, _scan_bwd_rule)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan_bhsm(r, k, v, w, u, *, chunk: int = 128,
                    interpret: bool = False):
    """r,k,v,w: (B, H, S, M); u: (H, M).
    Returns y: (B, H, S, M), final state (B, H, M, M) f32.
    Differentiable in every array input."""
    B, H, S, M = r.shape
    c, S_p = pick_block(S, chunk)
    # w = 1, k = v = 0 on the pad: the state passes through untouched, so
    # the emitted final state is exact and padded y rows are zero.
    r = pad_axis(r, S_p, axis=2)
    k = pad_axis(k, S_p, axis=2)
    v = pad_axis(v, S_p, axis=2)
    w = pad_axis(w, S_p, axis=2, value=1.0)
    y, s_final = _scan(r, k, v, w, u, c, interpret)
    return y[:, :, :S], s_final
