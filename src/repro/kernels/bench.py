"""Microbenchmark harness for the Pallas kernel tier (feeds fig23).

Closes the predict↔measure loop at the kernel layer: every plan/schedule/
composition decision is priced from the analytic tables in
``core.profiling`` (HardwareSpec peak FLOPs × MXU utilization), but until
now nothing compared those prices against what the kernels actually do.
This module times forward and forward+backward executions of the three
kernels across the profiler's pow2 shape buckets — the same
``runtime.calibration.shape_bucket`` keys the scheduler corrects with —
prices the identical shapes analytically, and can seed the measured ratios
straight into ``OnlineCalibrator`` cells so the search prices modules from
measured kernel time when a bench has run.

Host-unit normalization: on a CPU container the kernels execute in Pallas
interpret mode, ~1e6× slower than the TPU v5e the analytic tables price;
on a real TPU the constant is ~1.  ``normalize`` therefore folds out one
scalar *unit* per (kernel, direction) — the geomean of measured/analytic —
so the per-bucket ratio validates *shape-scaling fidelity* (does doubling
the sequence double the time the way the FLOP model says?), which is the
property the planner's relative decisions depend on.  The unit itself is
what a calibrator cell learns.
"""
from __future__ import annotations

import math
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.profiling.analytic import V5E, HardwareSpec
from repro.core.profiling.flops import TRAIN_MULT
from repro.kernels import ops
from repro.runtime.calibration import OnlineCalibrator, shape_bucket

# ---------------------------------------------------------------------- #
# Kernel-level FLOP counts (forward), consistent with core.profiling.flops
# ---------------------------------------------------------------------- #
def attention_flops(B: int, H: int, S: int, D: int, *, causal: bool) -> float:
    """score + AV matmuls: 2·2·B·S·S·H·D, halved under causal masking —
    the ``score_av`` term of ``flops._attn_layer``."""
    f = 4.0 * B * S * S * H * D
    return f * 0.5 if causal else f


def mamba_flops(B: int, S: int, di: int, N: int) -> float:
    """Selective-scan term of ``flops._mamba_layer``: 6·B·S·di·N."""
    return 6.0 * B * S * di * N


def rwkv6_flops(B: int, H: int, S: int, M: int) -> float:
    """WKV recurrence term of ``flops._rwkv_layer`` with d = H·M:
    6·B·S·(H·M)·M."""
    return 6.0 * B * S * H * M * M


def analytic_seconds(flops: float, hw: HardwareSpec = V5E) -> float:
    """The tables' price for ``flops`` of kernel work on one chip."""
    return flops / (hw.peak_flops * hw.base_mxu_util)


# ---------------------------------------------------------------------- #
# Timing
# ---------------------------------------------------------------------- #
def _time_fn(fn, *args, iters: int, warmup: int = 1) -> List[float]:
    """Per-iteration wall times (s), after ``warmup`` compile/cache calls."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    out = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        out.append(time.perf_counter() - t0)
    return out


def _case_attention(S: int, *, B: int, KH: int, G: int, D: int, causal: bool):
    key = jax.random.PRNGKey(S)
    kq, kk, kv = jax.random.split(key, 3)
    H = KH * G
    q = jax.random.normal(kq, (B, S, H, D), jnp.float32)
    k = jax.random.normal(kk, (B, S, KH, D), jnp.float32)
    v = jax.random.normal(kv, (B, S, KH, D), jnp.float32)
    seg = jnp.ones((B, S), jnp.int32)

    def fwd(q, k, v):
        return ops.packed_flash_attention(q, k, v, segment_ids=seg,
                                          causal=causal)

    return fwd, (q, k, v), attention_flops(B, H, S, D, causal=causal)


def _case_mamba(S: int, *, B: int, di: int, N: int):
    key = jax.random.PRNGKey(S + 1)
    ks = jax.random.split(key, 6)
    u = jax.random.normal(ks[0], (B, S, di), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, di)) - 1.0)
    B_t = jax.random.normal(ks[2], (B, S, N), jnp.float32)
    C_t = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[4], (di, N)) * 0.5)
    D = jax.random.normal(ks[5], (di,), jnp.float32)

    def fwd(u, dt, B_t, C_t, A, D):
        y, _ = ops.mamba_scan(u, dt, B_t, C_t, A, D)
        return y

    return fwd, (u, dt, B_t, C_t, A, D), mamba_flops(B, S, di, N)


def _case_rwkv6(S: int, *, B: int, H: int, M: int):
    key = jax.random.PRNGKey(S + 2)
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (B, S, H, M), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, M), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, M), jnp.float32)
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, S, H, M)) * 0.5))
    u = jax.random.normal(ks[4], (H, M), jnp.float32)

    def fwd(r, k, v, w):
        y, _ = ops.rwkv6_scan(r, k, v, w, u)
        return y

    return fwd, (r, k, v, w), rwkv6_flops(B, H, S, M)


_CASES = {"attention": _case_attention, "mamba": _case_mamba,
          "rwkv6": _case_rwkv6}

# bench defaults: modest model dims so interpret-mode runs stay snappy;
# the swept axis is the sequence length (the profiler's bucketed shape)
DEFAULT_DIMS: Dict[str, dict] = {
    "attention": dict(B=1, KH=2, G=2, D=64, causal=True),
    "mamba": dict(B=1, di=128, N=16),
    "rwkv6": dict(B=1, H=2, M=32),
}


def bench_kernel(kernel: str, seqs: Sequence[int], *, iters: int = 3,
                 hw: HardwareSpec = V5E, dims: Optional[dict] = None
                 ) -> List[dict]:
    """Time fwd and fwd+bwd across ``seqs``; one row per (S, direction).

    Rows carry the raw per-iteration times (``times_s``) so a calibrator
    can be seeded with every observation, plus the analytic price of the
    same shape (bwd priced at ``TRAIN_MULT − 1`` × fwd, the standard
    backward ≈ 2× forward count the tables use)."""
    case = _CASES[kernel]
    dims = dict(DEFAULT_DIMS[kernel], **(dims or {}))
    rows = []
    for S in seqs:
        fwd, args, f_fwd = case(int(S), **dims)

        def fwdbwd(*a):
            loss = lambda *aa: jnp.sum(fwd(*aa))        # noqa: E731
            l, grads = jax.value_and_grad(loss, argnums=tuple(
                range(len(a))))(*a)
            return (l, grads)

        for direction, fn, flops in (
                ("fwd", fwd, f_fwd),
                ("fwdbwd", fwdbwd, f_fwd * TRAIN_MULT)):
            times = _time_fn(fn, *args, iters=iters)
            rows.append({
                "kernel": kernel,
                "direction": direction,
                "tokens": int(S),
                "bucket": shape_bucket(float(S)),
                "flops": flops,
                "analytic_s": analytic_seconds(flops, hw),
                "times_s": times,
                "measured_s": float(sorted(times)[len(times) // 2]),
            })
    return rows


def normalize(rows: List[dict]) -> List[dict]:
    """Add the host unit (per-(kernel, direction) geomean measured/analytic)
    and the unit-normalized ``ratio`` to every row, in place."""
    groups: Dict[tuple, List[dict]] = {}
    for r in rows:
        groups.setdefault((r["kernel"], r["direction"]), []).append(r)
    for grp in groups.values():
        logs = [math.log(r["measured_s"] / r["analytic_s"]) for r in grp
                if r["measured_s"] > 0 and r["analytic_s"] > 0]
        unit = math.exp(sum(logs) / len(logs)) if logs else float("nan")
        for r in grp:
            r["unit"] = unit
            denom = unit * r["analytic_s"]
            r["ratio"] = r["measured_s"] / denom if denom > 0 else float("nan")
    return rows


def seed_calibrator(cal: OnlineCalibrator, rows: List[dict], *,
                    module: str = "llm", tp: int = 1) -> int:
    """Feed every benchmarked iteration into calibrator cells keyed exactly
    like the scheduler's observations ((module, shape_bucket(tokens), tp);
    the online scheduler names its decoder module "llm").  The *predicted*
    side is the unit-normalized analytic price, so the learned cell ratio
    is the same shape-residual the ratio rows report.  Returns the number
    of observations fed; with ``iters ≥ 2`` each touched cell matures past
    ``min_obs`` immediately."""
    n = 0
    for r in rows:
        pred = r.get("unit", float("nan")) * r["analytic_s"]
        if not (pred > 0):
            continue
        for t in r["times_s"]:
            cal.observe(module, float(r["tokens"]), tp, pred, t)
            n += 1
    return n
