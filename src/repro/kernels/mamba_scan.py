"""Mamba-1 selective scan — chunked Pallas TPU kernel.

    h_t = exp(dt_t ⊗ A) ⊙ h_{t-1} + (dt_t ⊙ u_t) ⊗ B_t
    y_t = h_t · C_t + D ⊙ u_t

TPU adaptation: time is chunked; channels are blocked so each program
instance owns a (c_blk, N) state tile in VMEM scratch carried across chunk
iterations.  Grid (B, n_cblk, n_chunks), chunk axis innermost/sequential.
B_t/C_t (shared across channels) are re-read per channel block — they are
(chunk, N) tiles, tiny next to the (chunk, c_blk) channel streams.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(u_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, y_ref, h_scr,
            *, n_chunks: int, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    A = a_ref[...].astype(jnp.float32)                 # (c_blk, N)
    D = d_ref[...].astype(jnp.float32)                 # (c_blk,)

    def step(t, h):
        u_t = u_ref[0, t].astype(jnp.float32)          # (c_blk,)
        dt_t = dt_ref[0, t].astype(jnp.float32)        # (c_blk,)
        b_t = b_ref[0, t].astype(jnp.float32)          # (N,)
        c_t = c_ref[0, t].astype(jnp.float32)          # (N,)
        decay = jnp.exp(dt_t[:, None] * A)             # (c_blk, N)
        h = h * decay + (dt_t * u_t)[:, None] * b_t[None, :]
        y = jnp.sum(h * c_t[None, :], axis=1) + D * u_t
        y_ref[0, t] = y.astype(y_ref.dtype)
        return h

    h_scr[...] = jax.lax.fori_loop(0, chunk, step, h_scr[...])


def _pick(s: int, target: int) -> int:
    b = min(s, target)
    while s % b:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("chunk", "c_blk", "interpret"))
def mamba_scan_bsd(u, dt, B_t, C_t, A, D, *, chunk: int = 128,
                   c_blk: int = 512, interpret: bool = False):
    """u, dt: (B, S, di); B_t, C_t: (B, S, N); A: (di, N); D: (di,).
    Returns y: (B, S, di)."""
    B, S, di = u.shape
    N = A.shape[1]
    c = _pick(S, chunk)
    cb = _pick(di, c_blk)
    n_chunks, n_cblk = S // c, di // cb
    kernel = functools.partial(_kernel, n_chunks=n_chunks, chunk=c)
    y = pl.pallas_call(
        kernel,
        grid=(B, n_cblk, n_chunks),
        in_specs=[
            pl.BlockSpec((1, c, cb), lambda b, j, i: (b, i, j)),
            pl.BlockSpec((1, c, cb), lambda b, j, i: (b, i, j)),
            pl.BlockSpec((1, c, N), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, c, N), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((cb, N), lambda b, j, i: (j, 0)),
            pl.BlockSpec((cb,), lambda b, j, i: (j,)),
        ],
        out_specs=pl.BlockSpec((1, c, cb), lambda b, j, i: (b, i, j)),
        out_shape=jax.ShapeDtypeStruct((B, S, di), u.dtype),
        scratch_shapes=[pltpu.VMEM((cb, N), jnp.float32)],
        interpret=interpret,
    )(u, dt, B_t, C_t, A, D)
    return y
