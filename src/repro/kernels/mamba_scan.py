"""Mamba-1 selective scan — chunked Pallas TPU kernel, forward + custom VJP.

    h_t = exp(dt_t ⊗ A) ⊙ h_{t-1} + (dt_t ⊙ u_t) ⊗ B_t
    y_t = h_t · C_t + D ⊙ u_t

TPU adaptation: time is chunked; channels are blocked so each program
instance owns a (c_blk, N) state tile in VMEM scratch carried across chunk
iterations.  Grid (B, n_cblk, n_chunks), chunk axis innermost/sequential.
B_t/C_t (shared across channels) are re-read per channel block — they are
(chunk, N) tiles, tiny next to the (chunk, c_blk) channel streams.

Backward (``docs/kernels.md``): the forward additionally emits each chunk's
*initial* state h_init (B, n_chunks, c_blk·n_cblk, N); the backward walks
chunks in reverse (index maps close over ``n_chunks − 1 − i``), replays the
chunk forward from h_init into a (chunk, c_blk, N) VMEM history, then runs
the adjoint recurrence

    g_t      = G_t + ŷ_t ⊗ C_t            (G carried across chunks in VMEM)
    G_{t-1}  = g_t ⊙ decay_t

per step t descending, producing du/ddt in place and *partial* parameter
grads: dB/dC get a leading channel-block axis and dA/dD a leading batch
axis — Pallas output accumulation is only safe across consecutive
innermost-grid revisits, so cross-(block, batch) sums happen outside the
kernel.  Non-multiple lengths are padded (``repro.kernels.blocking``) with
zeros: dt = 0 makes a padded step the identity (decay = 1, no input), so
outputs, states and gradients of real positions are exact.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.blocking import pad_axis, pick_block


def _fwd_kernel(u_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, y_ref, hinit_ref,
                h_scr, *, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    hinit_ref[0, 0] = h_scr[...]                       # this chunk's h_{-1}

    A = a_ref[...].astype(jnp.float32)                 # (c_blk, N)
    D = d_ref[...].astype(jnp.float32)                 # (c_blk,)

    def step(t, h):
        u_t = u_ref[0, t].astype(jnp.float32)          # (c_blk,)
        dt_t = dt_ref[0, t].astype(jnp.float32)        # (c_blk,)
        b_t = b_ref[0, t].astype(jnp.float32)          # (N,)
        c_t = c_ref[0, t].astype(jnp.float32)          # (N,)
        decay = jnp.exp(dt_t[:, None] * A)             # (c_blk, N)
        h = h * decay + (dt_t * u_t)[:, None] * b_t[None, :]
        y = jnp.sum(h * c_t[None, :], axis=1) + D * u_t
        y_ref[0, t] = y.astype(y_ref.dtype)
        return h

    h_scr[...] = jax.lax.fori_loop(0, chunk, step, h_scr[...])


def _bwd_kernel(u_ref, dt_ref, b_ref, c_ref, a_ref, d_ref, hinit_ref, dy_ref,
                du_ref, ddt_ref, db_ref, dc_ref, da_ref, dd_ref,
                g_scr, hist_scr, *, chunk: int):
    """One reversed-order chunk of the adjoint scan (see module docstring).

    hist_scr[t] holds the replayed pre-state h_{t-1}; g_scr carries the
    state adjoint G across (reversed) chunk iterations."""
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():                                       # last chunk first
        g_scr[...] = jnp.zeros_like(g_scr)

    A = a_ref[...].astype(jnp.float32)                 # (c_blk, N)
    D = d_ref[...].astype(jnp.float32)                 # (c_blk,)

    def replay(t, h):
        hist_scr[t] = h
        dt_t = dt_ref[0, t].astype(jnp.float32)
        u_t = u_ref[0, t].astype(jnp.float32)
        b_t = b_ref[0, t].astype(jnp.float32)
        decay = jnp.exp(dt_t[:, None] * A)
        return h * decay + (dt_t * u_t)[:, None] * b_t[None, :]

    jax.lax.fori_loop(0, chunk, replay, hinit_ref[0, 0].astype(jnp.float32))

    def bstep(s, carry):
        g, da_acc, dd_acc = carry
        t = chunk - 1 - s
        u_t = u_ref[0, t].astype(jnp.float32)
        dt_t = dt_ref[0, t].astype(jnp.float32)
        b_t = b_ref[0, t].astype(jnp.float32)
        c_t = c_ref[0, t].astype(jnp.float32)
        dy_t = dy_ref[0, t].astype(jnp.float32)        # (c_blk,)
        h_prev = hist_scr[t]                           # (c_blk, N)
        decay = jnp.exp(dt_t[:, None] * A)
        x_t = dt_t * u_t
        h_t = h_prev * decay + x_t[:, None] * b_t[None, :]

        gt = g + dy_t[:, None] * c_t[None, :]          # full dL/dh_t
        dc_ref[0, 0, t] = jnp.sum(dy_t[:, None] * h_t, axis=0)
        db_ref[0, 0, t] = jnp.sum(gt * x_t[:, None], axis=0)
        gh = gt * h_prev * decay                       # d(decay) chain
        dx = jnp.sum(gt * b_t[None, :], axis=1)
        ddt_ref[0, t] = dx * u_t + jnp.sum(gh * A, axis=1)
        du_ref[0, t] = dx * dt_t + D * dy_t
        da_acc = da_acc + gh * dt_t[:, None]
        dd_acc = dd_acc + dy_t * u_t
        return gt * decay, da_acc, dd_acc

    g, da_acc, dd_acc = jax.lax.fori_loop(
        0, chunk, bstep,
        (g_scr[...], jnp.zeros_like(g_scr), jnp.zeros_like(d_ref,
                                                           dtype=jnp.float32)))
    g_scr[...] = g

    @pl.when(ic == 0)
    def _first():
        da_ref[0] = da_acc
        dd_ref[0] = dd_acc

    @pl.when(ic > 0)
    def _rest():
        da_ref[0] += da_acc
        dd_ref[0] += dd_acc


def _fwd_call(u, dt, B_t, C_t, A, D, c, cb, interpret):
    B, S, di = u.shape
    N = A.shape[1]
    n_chunks, n_cblk = S // c, di // cb
    return pl.pallas_call(
        functools.partial(_fwd_kernel, chunk=c),
        grid=(B, n_cblk, n_chunks),
        in_specs=[
            pl.BlockSpec((1, c, cb), lambda b, j, i: (b, i, j)),
            pl.BlockSpec((1, c, cb), lambda b, j, i: (b, i, j)),
            pl.BlockSpec((1, c, N), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((1, c, N), lambda b, j, i: (b, i, 0)),
            pl.BlockSpec((cb, N), lambda b, j, i: (j, 0)),
            pl.BlockSpec((cb,), lambda b, j, i: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, cb), lambda b, j, i: (b, i, j)),
            pl.BlockSpec((1, 1, cb, N), lambda b, j, i: (b, i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, di), u.dtype),
            jax.ShapeDtypeStruct((B, n_chunks, di, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((cb, N), jnp.float32)],
        interpret=interpret,
    )(u, dt, B_t, C_t, A, D)


def _bwd_call(u, dt, B_t, C_t, A, D, h_init, dy, c, cb, interpret):
    B, S, di = u.shape
    N = A.shape[1]
    n_chunks, n_cblk = S // c, di // cb
    rev = n_chunks - 1                                 # reversed chunk walk
    f32 = jnp.float32
    return pl.pallas_call(
        functools.partial(_bwd_kernel, chunk=c),
        grid=(B, n_cblk, n_chunks),
        in_specs=[
            pl.BlockSpec((1, c, cb), lambda b, j, i: (b, rev - i, j)),
            pl.BlockSpec((1, c, cb), lambda b, j, i: (b, rev - i, j)),
            pl.BlockSpec((1, c, N), lambda b, j, i: (b, rev - i, 0)),
            pl.BlockSpec((1, c, N), lambda b, j, i: (b, rev - i, 0)),
            pl.BlockSpec((cb, N), lambda b, j, i: (j, 0)),
            pl.BlockSpec((cb,), lambda b, j, i: (j,)),
            pl.BlockSpec((1, 1, cb, N), lambda b, j, i: (b, rev - i, j, 0)),
            pl.BlockSpec((1, c, cb), lambda b, j, i: (b, rev - i, j)),
        ],
        out_specs=[
            pl.BlockSpec((1, c, cb), lambda b, j, i: (b, rev - i, j)),
            pl.BlockSpec((1, c, cb), lambda b, j, i: (b, rev - i, j)),
            pl.BlockSpec((1, 1, c, N), lambda b, j, i: (j, b, rev - i, 0)),
            pl.BlockSpec((1, 1, c, N), lambda b, j, i: (j, b, rev - i, 0)),
            pl.BlockSpec((1, cb, N), lambda b, j, i: (b, j, 0)),
            pl.BlockSpec((1, cb), lambda b, j, i: (b, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, di), f32),          # du
            jax.ShapeDtypeStruct((B, S, di), f32),          # ddt
            jax.ShapeDtypeStruct((n_cblk, B, S, N), f32),   # dB partial
            jax.ShapeDtypeStruct((n_cblk, B, S, N), f32),   # dC partial
            jax.ShapeDtypeStruct((B, di, N), f32),          # dA partial
            jax.ShapeDtypeStruct((B, di), f32),             # dD partial
        ],
        scratch_shapes=[pltpu.VMEM((cb, N), jnp.float32),
                        pltpu.VMEM((c, cb, N), jnp.float32)],
        interpret=interpret,
    )(u, dt, B_t, C_t, A, D, h_init, dy)


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8))
def _scan(u, dt, B_t, C_t, A, D, c, cb, interpret):
    y, _ = _fwd_call(u, dt, B_t, C_t, A, D, c, cb, interpret)
    return y


def _scan_fwd_rule(u, dt, B_t, C_t, A, D, c, cb, interpret):
    y, h_init = _fwd_call(u, dt, B_t, C_t, A, D, c, cb, interpret)
    return y, (u, dt, B_t, C_t, A, D, h_init)


def _scan_bwd_rule(c, cb, interpret, res, dy):
    u, dt, B_t, C_t, A, D, h_init = res
    du, ddt, dB_p, dC_p, dA_p, dD_p = _bwd_call(
        u, dt, B_t, C_t, A, D, h_init, dy, c, cb, interpret)
    return (du.astype(u.dtype), ddt.astype(dt.dtype),
            jnp.sum(dB_p, axis=0).astype(B_t.dtype),
            jnp.sum(dC_p, axis=0).astype(C_t.dtype),
            jnp.sum(dA_p, axis=0).astype(A.dtype),
            jnp.sum(dD_p, axis=0).astype(D.dtype))


_scan.defvjp(_scan_fwd_rule, _scan_bwd_rule)


@functools.partial(jax.jit, static_argnames=("chunk", "c_blk", "interpret"))
def mamba_scan_bsd(u, dt, B_t, C_t, A, D, *, chunk: int = 128,
                   c_blk: int = 512, interpret: bool = False):
    """u, dt: (B, S, di); B_t, C_t: (B, S, N); A: (di, N); D: (di,).
    Returns y: (B, S, di).  Differentiable in every array input."""
    B, S, di = u.shape
    c, S_p = pick_block(S, chunk)
    cb, di_p = pick_block(di, c_blk)
    # dt = 0 on the pad makes every padded step an identity; padded
    # channels (A = D = 0) contribute nothing and are sliced off.
    u = pad_axis(pad_axis(u, S_p, axis=1), di_p, axis=2)
    dt = pad_axis(pad_axis(dt, S_p, axis=1), di_p, axis=2)
    B_t = pad_axis(B_t, S_p, axis=1)
    C_t = pad_axis(C_t, S_p, axis=1)
    A = pad_axis(A, di_p, axis=0)
    D = pad_axis(D, di_p, axis=0)
    y = _scan(u, dt, B_t, C_t, A, D, c, cb, interpret)
    return y[:, :S, :di]
